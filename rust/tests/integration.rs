//! Cross-module integration tests: full training runs through the real
//! PJRT executables with failures injected and every recovery strategy
//! exercised end-to-end. These are the Rust-side counterpart of the
//! paper's evaluation protocol, shrunk to the `tiny` preset.

use checkfree::config::{FailureSpec, LinkPath, Overlap, PlaneMode, Strategy, TraceMode, TrainConfig};
use checkfree::coordinator::Trainer;
use checkfree::data::Domain;
use checkfree::experiments;
use checkfree::failures::ChurnProcessKind;
use checkfree::metrics::EventKind;

fn cfg(strategy: Strategy, iterations: u64, rate: f64, seed: u64) -> TrainConfig {
    TrainConfig {
        model: "tiny".into(),
        strategy,
        iterations,
        microbatches_per_iter: 2,
        failure: FailureSpec::PerIteration { rate },
        checkpoint_every: 5,
        eval_every: 5,
        seed,
        ..TrainConfig::default()
    }
}

#[test]
fn every_strategy_survives_churn_and_converges() {
    for strategy in [
        Strategy::Checkpoint,
        Strategy::Redundant,
        Strategy::CheckFree,
        Strategy::CheckFreePlus,
    ] {
        let mut t = Trainer::new(cfg(strategy, 20, 0.05, 42)).unwrap();
        let s = t.run().unwrap_or_else(|e| panic!("{strategy:?}: {e:#}"));
        let first = t.record.curve.first().unwrap().train_loss;
        assert!(
            s.final_train_loss < first - 0.5,
            "{strategy:?} failed to converge: {first} → {}",
            s.final_train_loss
        );
    }
}

#[test]
fn identical_failure_pattern_across_strategies() {
    // paper §5.1: "the failure patterns between tests are the same"
    let mut failures_by_strategy = Vec::new();
    for strategy in [Strategy::Checkpoint, Strategy::CheckFree, Strategy::CheckFreePlus] {
        let mut t = Trainer::new(cfg(strategy, 12, 0.08, 77)).unwrap();
        t.run().unwrap();
        let pattern: Vec<(u64, usize)> = t
            .record
            .events
            .iter()
            .filter(|e| e.kind == EventKind::StageFailure)
            .map(|e| (e.iteration, e.stage.unwrap()))
            .collect();
        failures_by_strategy.push(pattern);
    }
    assert!(!failures_by_strategy[0].is_empty(), "seed produced no failures");
    assert_eq!(failures_by_strategy[0], failures_by_strategy[1]);
    assert_eq!(failures_by_strategy[1], failures_by_strategy[2]);
}

#[test]
fn redundant_equals_no_failure_convergence() {
    // paper §5.3: redundant computation ≡ fault-free training in
    // convergence terms — bit-identical here because recovery is exact.
    let mut clean = Trainer::new(cfg(Strategy::None, 8, 0.0, 5)).unwrap();
    clean.run().unwrap();
    let mut red = Trainer::new(cfg(Strategy::Redundant, 8, 0.2, 5)).unwrap();
    red.run().unwrap();
    assert!(red.record.failures() > 0, "rate 0.2 must produce failures");
    let a: Vec<f32> = clean.record.curve.iter().map(|p| p.train_loss).collect();
    let b: Vec<f32> = red.record.curve.iter().map(|p| p.train_loss).collect();
    assert_eq!(a, b, "redundant recovery must not perturb the loss curve");
}

#[test]
fn checkfree_recovery_perturbs_but_training_recovers() {
    let mut t = Trainer::new(cfg(Strategy::CheckFree, 24, 0.0, 9)).unwrap();
    t.force_failure(12, 1);
    t.run().unwrap();
    let curve = &t.record.curve;
    let before = curve.iter().find(|p| p.iteration == 12).unwrap().train_loss;
    let at = curve.iter().find(|p| p.iteration == 13).unwrap().train_loss;
    let end = curve.last().unwrap().train_loss;
    // the reinit bumps the loss, then training recovers below the bump
    assert!(at > before - 0.1, "expected perturbation at recovery ({before} → {at})");
    assert!(end < at, "training must keep improving after recovery ({at} → {end})");
}

#[test]
fn checkpoint_rollback_loses_progress_checkfree_does_not() {
    let seed = 1234;
    let mut ck = Trainer::new(cfg(Strategy::Checkpoint, 16, 0.0, seed)).unwrap();
    ck.force_failure(9, 1);
    ck.run().unwrap();
    let mut cf = Trainer::new(cfg(Strategy::CheckFree, 16, 0.0, seed)).unwrap();
    cf.force_failure(9, 1);
    cf.run().unwrap();
    // same data, same failure: checkpoint redoes iterations 6..9 → its
    // engine ends at an earlier effective iteration
    assert!(ck.engine.iteration < cf.engine.iteration);
    assert!(ck.record.events.iter().any(|e| e.kind == EventKind::Rollback));
}

#[test]
fn per_stage_planes_survive_churn_identically_to_shared() {
    // End-to-end plane-mode parity under real failures: the same churny
    // CheckFree+ run on one shared client and on one client per stage
    // must produce the same loss curve bit for bit — recovery rewrites
    // land on the failed stage's own client via the per-plane mirror
    // refresh, and link copies move bytes without changing them.
    let mut curves = Vec::new();
    for plane_mode in [PlaneMode::Shared, PlaneMode::PerStage] {
        let mut c = cfg(Strategy::CheckFreePlus, 12, 0.0, 31);
        c.plane_mode = plane_mode;
        let mut t = Trainer::new(c).unwrap();
        t.force_failure(4, 1); // swap-partner copy path
        t.force_failure(8, 2); // boundary / weighted path
        t.run().unwrap();
        assert_eq!(t.record.failures(), 2);
        let curve: Vec<u32> = t.record.curve.iter().map(|p| p.train_loss.to_bits()).collect();
        curves.push(curve);
    }
    assert_eq!(curves[0], curves[1], "plane modes diverged under churn");
}

#[test]
fn direct_and_staged_links_survive_churn_identically() {
    // End-to-end link-path parity under real failures: the same churny
    // CheckFree+ run on per-stage planes must produce the same loss
    // curve bit for bit whether link copies take the plugin's direct
    // cross-client transfer or the staged device→host→device fallback
    // — which path moves the bytes cannot matter to recovery either.
    // Forced `Direct` (not `Auto`) so a plugin that silently lacks
    // cross-client transfer fails this test instead of vacuously
    // passing via the staged fallback.
    let mut curves = Vec::new();
    for link_path in [LinkPath::Staged, LinkPath::Direct] {
        let mut c = cfg(Strategy::CheckFreePlus, 12, 0.0, 53);
        c.plane_mode = PlaneMode::PerStage;
        c.link_path = link_path;
        let mut t = Trainer::new(c).unwrap();
        t.force_failure(4, 1);
        t.force_failure(8, 2);
        t.run().unwrap();
        assert_eq!(t.record.failures(), 2);
        let curve: Vec<u32> = t.record.curve.iter().map(|p| p.train_loss.to_bits()).collect();
        curves.push(curve);
    }
    assert_eq!(curves[0], curves[1], "link paths diverged under churn");
}

#[test]
fn overlapped_links_survive_churn_identically_to_blocking() {
    // End-to-end overlap parity under real failures: the same churny
    // CheckFree+ run on per-stage planes must produce the same loss
    // curve bit for bit whether link copies are prefetched at issue
    // time (`--overlap on`) or performed in the consumer's call path
    // (`--overlap off`). Recovery is the interesting part: the trainer
    // only rewrites params / invalidates the litcache after
    // `run_iteration` has joined every worker, so no prefetched link
    // can be in flight when the rewrite lands — this test pins that
    // quiesce rule through two forced failures on both recovery paths.
    let mut curves = Vec::new();
    for overlap in [Overlap::Off, Overlap::On] {
        let mut c = cfg(Strategy::CheckFreePlus, 12, 0.0, 53);
        c.plane_mode = PlaneMode::PerStage;
        c.link_path = LinkPath::Auto;
        c.overlap = overlap;
        let mut t = Trainer::new(c).unwrap();
        t.force_failure(4, 1); // swap-partner copy path
        t.force_failure(8, 2); // boundary / weighted path
        t.run().unwrap();
        assert_eq!(t.record.failures(), 2);
        let curve: Vec<u32> = t.record.curve.iter().map(|p| p.train_loss.to_bits()).collect();
        curves.push(curve);
    }
    assert_eq!(curves[0], curves[1], "overlap on/off diverged under churn");
}

#[test]
fn device_optimizer_survives_churn_identically_to_host() {
    // End-to-end optimizer-path parity under real failures: the same
    // churny CheckFree+ run must produce the same loss curve bit for bit
    // whether Adam steps on the host (pulling every gradient) or fused
    // on-plane with lazily materialized host state. Recovery is the
    // interesting part — both forced failures read neighbour weights,
    // which on the device path only exist on the host because the
    // strategy's staleness guard pulled them first.
    use checkfree::config::OptimizerPath;
    let mk = |path| {
        let mut c = cfg(Strategy::CheckFreePlus, 12, 0.0, 31);
        c.optimizer_path = path;
        let mut t = Trainer::new(c).unwrap();
        t.force_failure(4, 1); // swap-partner copy path
        t.force_failure(8, 2); // boundary / weighted path
        t
    };
    let mut host = mk(OptimizerPath::Host);
    let mut dev = mk(OptimizerPath::Device);
    assert_eq!(dev.engine.optimizer_path(), OptimizerPath::Device);
    host.run().unwrap();
    dev.run().unwrap();
    assert_eq!(host.record.failures(), 2);
    assert_eq!(dev.record.failures(), 2);
    let a: Vec<u32> = host.record.curve.iter().map(|p| p.train_loss.to_bits()).collect();
    let b: Vec<u32> = dev.record.curve.iter().map(|p| p.train_loss.to_bits()).collect();
    assert_eq!(a, b, "optimizer paths diverged under churn");
    dev.engine.materialize_host_state().unwrap();
    for (h, d) in host.engine.stages.iter().zip(&dev.engine.stages) {
        assert_eq!(h.params, d.params, "stage {} weights diverged", h.index);
    }
}

#[test]
fn fig2_reinit_ordering_weighted_beats_random() {
    let runs = experiments::fig2_init_strategies("tiny", 16, &[(6, 1), (11, 2)], 2).unwrap();
    let by = |label: &str| {
        runs.iter().find(|r| r.label == label).unwrap().curve.last().unwrap().train_loss
    };
    assert!(by("weighted") < by("random"), "weighted {} random {}", by("weighted"), by("random"));
}

#[test]
fn checkfree_plus_swap_partner_similarity() {
    // After swap training, S1 and S2 see each other's slots; copying the
    // partner must land closer (in L2) to the lost stage than a random
    // stage would. We check the *mechanism*: recovery copies the partner.
    let mut t = Trainer::new(cfg(Strategy::CheckFreePlus, 10, 0.0, 3)).unwrap();
    t.run().unwrap();
    let s1 = &t.engine.stages[1].params;
    let s2 = &t.engine.stages[2].params;
    let d12: f64 = s1
        .iter()
        .zip(s2)
        .map(|(a, b)| {
            a.as_f32()
                .iter()
                .zip(b.as_f32())
                .map(|(&x, &y)| ((x - y) as f64).powi(2))
                .sum::<f64>()
        })
        .sum();
    assert!(d12.is_finite() && d12 > 0.0);
}

#[test]
fn perplexity_in_domain_beats_out_of_domain() {
    let mut t = Trainer::new(cfg(Strategy::None, 30, 0.0, 8)).unwrap();
    t.run().unwrap();
    let in_dom = t.engine.perplexity(Domain::Stories, 55, 2).unwrap();
    let out_dom = t.engine.perplexity(Domain::Arxiv, 55, 2).unwrap();
    assert!(
        in_dom < out_dom,
        "trained on stories: in-domain ppl {in_dom} must beat arxiv {out_dom}"
    );
}

#[test]
fn config_json_roundtrip_through_file() {
    let dir = std::env::temp_dir().join(format!("cfree-it-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("run.json");
    let cfg0 = cfg(Strategy::CheckFreePlus, 7, 0.01, 66);
    std::fs::write(&path, cfg0.to_json().to_string()).unwrap();
    let cfg1 = TrainConfig::from_json_file(&path).unwrap();
    assert_eq!(cfg1.strategy, Strategy::CheckFreePlus);
    assert_eq!(cfg1.iterations, 7);
    assert_eq!(cfg1.seed, 66);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn lr_boost_compounds_across_repeated_failures() {
    let mut t = Trainer::new(cfg(Strategy::CheckFree, 12, 0.0, 10)).unwrap();
    t.force_failure(4, 1);
    t.force_failure(8, 1);
    let base_lr = t.engine.stages[2].lr;
    t.run().unwrap();
    let boosted = t.engine.stages[1].lr;
    assert!(
        (boosted / base_lr - 1.21).abs() < 1e-3,
        "two recoveries → lr ×1.21, got ×{}",
        boosted / base_lr
    );
}

#[test]
fn churn_trace_record_then_replay_is_bitwise_identical() {
    // The scenario-factory determinism contract, end to end THROUGH
    // recovery: record a churny CheckFree run's tape, then replay the
    // tape on a fresh trainer — the failure schedule, recovery events,
    // and loss curve must match bit for bit.
    let dir = std::env::temp_dir().join(format!("cfree-trace-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let tape = dir.join("churn.jsonl");
    let tape_s = tape.to_str().unwrap().to_string();

    let pattern_of = |t: &Trainer| -> Vec<(u64, usize)> {
        t.record
            .events
            .iter()
            .filter(|e| e.kind == EventKind::StageFailure)
            .map(|e| (e.iteration, e.stage.unwrap()))
            .collect()
    };
    let recoveries_of = |t: &Trainer| -> Vec<u64> {
        t.record
            .events
            .iter()
            .filter(|e| e.kind == EventKind::Recovery)
            .map(|e| e.iteration)
            .collect()
    };

    let mut rec_cfg = cfg(Strategy::CheckFree, 16, 0.06, 911);
    rec_cfg.churn_process = ChurnProcessKind::Bursty;
    rec_cfg.churn_trace = Some(TraceMode::Record(tape_s.clone()));
    let mut recorded = Trainer::new(rec_cfg).unwrap();
    recorded.force_failure(6, 1); // guarantee at least one recovery on the tape
    recorded.run().unwrap();
    let rec_pattern = pattern_of(&recorded);
    assert!(!rec_pattern.is_empty(), "recording run produced no failures");
    assert!(!recoveries_of(&recorded).is_empty(), "no recovery on the tape");

    let mut rep_cfg = cfg(Strategy::CheckFree, 16, 0.0, 911);
    rep_cfg.churn_trace = Some(TraceMode::Replay(tape_s));
    let mut replayed = Trainer::new(rep_cfg).unwrap();
    replayed.run().unwrap();

    assert_eq!(pattern_of(&replayed), rec_pattern, "failure schedule diverged");
    assert_eq!(recoveries_of(&replayed), recoveries_of(&recorded), "recovery sequence diverged");
    let a: Vec<u32> = recorded.record.curve.iter().map(|p| p.train_loss.to_bits()).collect();
    let b: Vec<u32> = replayed.record.curve.iter().map(|p| p.train_loss.to_bits()).collect();
    assert_eq!(a, b, "loss curve diverged under trace replay");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn churn_processes_give_identical_patterns_across_strategies() {
    // §5.1's strategy-independence, extended to every arrival process:
    // for a fixed seed the schedule is a pure function of the process,
    // whatever recovery strategy consumes it.
    for churn in [ChurnProcessKind::Poisson, ChurnProcessKind::Bursty] {
        let mut patterns = Vec::new();
        for strategy in [Strategy::CheckFree, Strategy::CheckFreePlus] {
            let mut c = cfg(strategy, 12, 0.08, 77);
            c.churn_process = churn;
            let mut t = Trainer::new(c).unwrap();
            t.run().unwrap();
            let pattern: Vec<(u64, usize)> = t
                .record
                .events
                .iter()
                .filter(|e| e.kind == EventKind::StageFailure)
                .map(|e| (e.iteration, e.stage.unwrap()))
                .collect();
            patterns.push(pattern);
        }
        assert_eq!(patterns[0], patterns[1], "{} diverged across strategies", churn.label());
    }
}

// ---------------------------------------------------------------------------
// Wire transport: tcp-loopback ↔ in-process parity, ledger invariants,
// and the multi-process kill lane.
// ---------------------------------------------------------------------------

use checkfree::config::{ExecMode, LinkTransportKind};

fn wire_cfg(
    strategy: Strategy,
    exec_mode: ExecMode,
    transport: LinkTransportKind,
    iterations: u64,
    seed: u64,
) -> TrainConfig {
    let mut c = cfg(strategy, iterations, 0.0, seed);
    c.exec_mode = exec_mode;
    c.plane_mode = PlaneMode::PerStage;
    c.link_path = LinkPath::Auto;
    c.link_transport = transport;
    c.tier_backup_every = 2; // arms the tier for tiercheck legs
    c
}

fn loss_bits(t: &Trainer) -> Vec<u32> {
    t.record.curve.iter().map(|p| p.train_loss.to_bits()).collect()
}

#[test]
fn tcp_loopback_matches_in_process_across_exec_modes_and_strategies() {
    // THE tentpole acceptance gate: framing every cross-plane hop,
    // pushing it through a real socket, and staging it back must be
    // invisible to training — identical loss bits for every exec mode
    // × {none, checkfree, tiercheck}, with recovery traffic (weighted
    // averaging, tier restores) crossing the wire too.
    for exec_mode in [ExecMode::Sequential, ExecMode::Pipelined, ExecMode::Pipelined1F1B] {
        for strategy in [Strategy::None, Strategy::CheckFree, Strategy::TierCheck] {
            let mut curves = Vec::new();
            for transport in [LinkTransportKind::InProcess, LinkTransportKind::TcpLoopback] {
                let mut t =
                    Trainer::new(wire_cfg(strategy, exec_mode, transport, 6, 271)).unwrap();
                if strategy != Strategy::None {
                    t.force_failure(3, 1); // recovery must cross the wire
                }
                t.run().unwrap_or_else(|e| panic!("{strategy:?}/{exec_mode:?}: {e:#}"));
                if strategy != Strategy::None {
                    assert_eq!(t.record.failures(), 1);
                }
                curves.push(loss_bits(&t));
            }
            assert_eq!(
                curves[0], curves[1],
                "{strategy:?}/{exec_mode:?}: tcp-loopback diverged from in-process"
            );
        }
    }
}

#[test]
fn tcp_loopback_matches_in_process_on_a_replayed_churn_tape() {
    // Same tape, both transports: the full scenario factory (record →
    // replay) composes with the wire — identical failure schedules AND
    // identical loss bits.
    let dir = std::env::temp_dir().join(format!("cfree-wire-tape-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let tape = dir.join("churn.jsonl");
    let tape_s = tape.to_str().unwrap().to_string();

    let mut rec_cfg =
        wire_cfg(Strategy::CheckFree, ExecMode::Pipelined1F1B, LinkTransportKind::InProcess, 10, 97);
    rec_cfg.failure = FailureSpec::PerIteration { rate: 0.12 };
    rec_cfg.churn_process = ChurnProcessKind::Bursty;
    rec_cfg.churn_trace = Some(TraceMode::Record(tape_s.clone()));
    let mut recorded = Trainer::new(rec_cfg).unwrap();
    recorded.force_failure(4, 1);
    recorded.run().unwrap();
    assert!(recorded.record.failures() > 0, "tape is empty");

    let mut curves = Vec::new();
    for transport in [LinkTransportKind::InProcess, LinkTransportKind::TcpLoopback] {
        let mut c = wire_cfg(Strategy::CheckFree, ExecMode::Pipelined1F1B, transport, 10, 97);
        c.churn_trace = Some(TraceMode::Replay(tape_s.clone()));
        let mut t = Trainer::new(c).unwrap();
        t.run().unwrap();
        assert_eq!(t.record.failures(), recorded.record.failures());
        curves.push(loss_bits(&t));
    }
    assert_eq!(loss_bits(&recorded), curves[0], "replay diverged from the recording");
    assert_eq!(curves[0], curves[1], "transports diverged on the same tape");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn wire_ledger_bills_frames_and_keeps_the_overlap_invariant() {
    // Ledger contract on every transport: the overlap split always
    // accounts for every link copy, and the wire columns fire exactly
    // when bytes actually cross a socket — nonzero on tcp-loopback
    // (frame bytes strictly exceed payload bytes: CFW1 headers),
    // identically zero in-process.
    for (transport, overlap) in [
        (LinkTransportKind::InProcess, Overlap::On),
        (LinkTransportKind::TcpLoopback, Overlap::On),
        (LinkTransportKind::TcpLoopback, Overlap::Off),
    ] {
        let mut c = wire_cfg(Strategy::CheckFree, ExecMode::Pipelined1F1B, transport, 4, 19);
        c.overlap = overlap;
        let mut t = Trainer::new(c).unwrap();
        t.force_failure(2, 1);
        t.run().unwrap();
        let s = t.engine.transfer_ledger().snapshot();
        assert!(s.link_copies > 0, "{transport:?}: no cross-plane traffic measured");
        assert_eq!(
            s.link_overlapped + s.link_blocking,
            s.link_copies,
            "{transport:?}/{overlap:?}: overlap split lost a copy"
        );
        match transport {
            LinkTransportKind::InProcess => {
                assert_eq!(s.link_wire_bytes, 0, "in-process billed wire bytes");
                assert_eq!(s.link_wire_ns, 0, "in-process billed wire time");
            }
            LinkTransportKind::TcpLoopback => {
                assert!(
                    s.link_wire_bytes > s.link_bytes,
                    "tcp: frames ({}) must exceed payloads ({})",
                    s.link_wire_bytes,
                    s.link_bytes
                );
                assert!(s.link_wire_ns > 0, "tcp: wire time unbilled");
                assert_eq!(s.link_staged, s.link_copies, "tcp hops are staged at each end");
            }
        }
    }
}

#[test]
fn shaped_wan_profile_slows_the_wire_but_not_the_math() {
    // gcp-5region shaping composes with training: same loss bits as the
    // unshaped run (delay is not data), and the emulated per-hop delay
    // shows up in link_wire_ns. Scale is tiny so the test stays fast.
    let mut base = wire_cfg(Strategy::CheckFree, ExecMode::Pipelined, LinkTransportKind::InProcess, 4, 83);
    let mut shaped_cfg = base.clone();
    shaped_cfg.wan_profile = checkfree::config::WanProfile::Gcp5Region;
    shaped_cfg.wan_scale = 1e-6;

    let mut a = Trainer::new(std::mem::take(&mut base)).unwrap();
    a.force_failure(2, 1);
    a.run().unwrap();
    let mut b = Trainer::new(shaped_cfg).unwrap();
    b.force_failure(2, 1);
    b.run().unwrap();

    assert_eq!(loss_bits(&a), loss_bits(&b), "shaping changed the numbers");
    let (sa, sb) =
        (a.engine.transfer_ledger().snapshot(), b.engine.transfer_ledger().snapshot());
    assert_eq!(sa.link_wire_ns, 0, "unshaped in-process run billed wire time");
    assert!(sb.link_wire_ns > 0, "shaped run must bill the emulated delay");
    assert_eq!(sb.link_wire_bytes, 0, "shaped-over-in-process moves no frames");
}

#[test]
fn multi_process_cluster_survives_a_real_process_kill() {
    // The elastic-churn lane: stage wire endpoints are real OS
    // processes (spawned from the built binary), the forced failure
    // SIGKILLs one mid-run, and recovery completes over the respawned
    // replacement — with the loss curve bit-identical to the plain
    // in-process run of the same config. Killing a process IS the
    // failure event.
    use checkfree::coordinator::{ProcessKiller, StageCluster};
    use std::sync::{Arc, Mutex};

    let mut reference =
        Trainer::new(wire_cfg(Strategy::CheckFree, ExecMode::Pipelined1F1B, LinkTransportKind::InProcess, 6, 613))
            .unwrap();
    reference.force_failure(3, 1);
    reference.run().unwrap();

    let c = wire_cfg(Strategy::CheckFree, ExecMode::Pipelined1F1B, LinkTransportKind::TcpLoopback, 6, 613);
    let planes = checkfree::manifest::Manifest::load_config(
        checkfree::config::default_artifacts_root(),
        &c.model,
    )
    .unwrap()
    .config
    .body_stages
        + 1;
    let cluster = StageCluster::spawn(env!("CARGO_BIN_EXE_checkfree"), planes).unwrap();
    let first_pid = cluster.pid(1).unwrap();
    let cluster = Arc::new(Mutex::new(cluster));
    let transport = cluster.lock().unwrap().transport();
    let mut t = Trainer::new_with(
        c,
        Some(transport),
        Some(Box::new(ProcessKiller::new(Arc::clone(&cluster)))),
    )
    .unwrap();
    t.force_failure(3, 1);
    t.run().unwrap();

    assert_eq!(t.record.failures(), 1);
    {
        let cl = cluster.lock().unwrap();
        assert_eq!(cl.kills(), 1, "the forced failure must kill a real process");
        assert_ne!(cl.pid(1).unwrap(), first_pid, "stage 1 must be a respawned process");
    }
    let s = t.engine.transfer_ledger().snapshot();
    assert!(s.link_wire_bytes > 0, "cluster traffic must cross the wire");
    assert_eq!(s.link_overlapped + s.link_blocking, s.link_copies);
    assert_eq!(
        loss_bits(&reference),
        loss_bits(&t),
        "multi-process run diverged from the in-process reference"
    );
}

#[test]
fn wall_clock_accounting_is_consistent() {
    let mut t = Trainer::new(cfg(Strategy::CheckFree, 10, 0.0, 11)).unwrap();
    t.force_failure(5, 1);
    t.run().unwrap();
    let iter_time = 10.0 * checkfree::coordinator::PAPER_ITER_SECONDS;
    let event_cost = t.record.total_event_cost_s();
    assert!(
        (t.sim_time_s() - iter_time - event_cost).abs() < 1e-6,
        "sim time {} != iterations {} + events {}",
        t.sim_time_s(),
        iter_time,
        event_cost
    );
}
