//! Bench: the scenario-factory **coverage matrix** — every recovery
//! strategy × every churn arrival process × pipeline scales up to 1024
//! stages, each cell a full event-driven simulated training run
//! (`sim::simulate_coverage`). This is the artifact that proves the
//! simulator's thousand-stage scale-out: stream-churn cells report
//! `sampled_iterations ≪ iterations` (quiet spans jumped in closed
//! form), and the whole 36-cell matrix completes in bench time.
//!
//! Emits `BENCH_coverage.json` at the repo root (schema checked by
//! `scripts/check_bench_json.py`), so churn-regime coverage is diffable
//! across PRs and validated by the nightly `coverage-matrix` CI lane.
//!
//! Pass `--smoke` for quick runs: fewer iterations per cell, results
//! written to the **gitignored** `BENCH_coverage.smoke.json` sidecar so
//! smoke runs never clobber the committed trajectory. The matrix SHAPE
//! is identical in both modes — the 1024-stage scale is the point, and
//! the event-driven path keeps it cheap even at smoke budgets.

use std::time::Instant;

use checkfree::config::Strategy;
use checkfree::failures::ChurnProcessKind;
use checkfree::sim::{simulate_coverage, SimParams};
use checkfree::util::json::Json;

/// Per-stage per-iteration failure rate, constant across scales so the
/// cells stay comparable: deeper pipelines see proportionally more
/// events, which is exactly the regime being covered.
const RATE_PER_STAGE: f64 = 0.002;

const SCALES: [usize; 3] = [16, 128, 1024];
const STRATEGIES: [Strategy; 3] =
    [Strategy::CheckFree, Strategy::Checkpoint, Strategy::Redundant];

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let iterations: u64 = if smoke { 300 } else { 2_000 };
    let seed = 20250807u64;

    let mut cells: Vec<Json> = Vec::new();
    let mut all_finite = true;
    let mut sparse_ok = true;
    // Rate convergence is judged on the aggregate over all cells of a
    // process (small cells alone are too noisy for a hard gate; the
    // per-cell numbers are still in the artifact for eyeballing).
    let mut agg_failures = [0u64; 2]; // [bernoulli, poisson]
    let mut agg_stage_iters = [0f64; 2];

    println!("--- coverage matrix: strategy × churn process × scale ---");
    println!(
        "{:<16} {:<12} {:>6} {:>9} {:>9} {:>11} {:>10} {:>9}",
        "strategy", "churn", "stages", "failures", "sampled", "sim_hours", "rollbacks", "wall_ms"
    );
    for &stages in &SCALES {
        for strategy in STRATEGIES {
            for churn in ChurnProcessKind::ALL {
                // Correlated cells run in probing mode: region-scoped
                // co-failures are the point, so the no-two-adjacent
                // assumption is lifted for them (and only them).
                let allow_adjacent = churn == ChurnProcessKind::Correlated;
                let p = SimParams::coverage(stages, strategy, RATE_PER_STAGE, seed);
                let wall = Instant::now();
                let run = simulate_coverage(&p, churn, allow_adjacent, iterations);
                let wall_ms = wall.elapsed().as_secs_f64() * 1e3;

                all_finite &= run.sim_hours.is_finite();
                // Event-driven sparsity: stream churn must not consult
                // the injector once per iteration. Bernoulli is dense
                // by construction and exempt; bursty is dense only
                // inside burst windows so it still lands well below 1.
                if churn != ChurnProcessKind::Bernoulli {
                    sparse_ok &= run.sampled_iterations < run.iterations;
                }
                // Rate accounting for the independent-arrival processes
                // (bursty/correlated cluster events by design; their
                // long-run rates are pinned by propcheck instead).
                let slot = match churn {
                    ChurnProcessKind::Bernoulli => Some(0),
                    ChurnProcessKind::Poisson => Some(1),
                    _ => None,
                };
                if let Some(k) = slot {
                    agg_failures[k] += run.failures;
                    agg_stage_iters[k] += (stages - 1) as f64 * run.iterations as f64;
                }

                println!(
                    "{:<16} {:<12} {:>6} {:>9} {:>9} {:>11.2} {:>10} {:>9.1}",
                    strategy.label(),
                    churn.label(),
                    stages,
                    run.failures,
                    run.sampled_iterations,
                    run.sim_hours,
                    run.rollback_iterations,
                    wall_ms
                );
                cells.push(Json::obj(vec![
                    ("strategy", Json::str(strategy.label())),
                    ("churn_process", Json::str(churn.label())),
                    ("stages", Json::num(stages as f64)),
                    ("allow_adjacent", Json::Bool(allow_adjacent)),
                    ("rate_per_stage", Json::num(RATE_PER_STAGE)),
                    ("iterations", Json::num(run.iterations as f64)),
                    ("failures", Json::num(run.failures as f64)),
                    ("recoveries", Json::num(run.recoveries as f64)),
                    ("rollback_iterations", Json::num(run.rollback_iterations as f64)),
                    ("recovery_seconds", Json::num(run.recovery_seconds)),
                    ("checkpoint_stall_seconds", Json::num(run.checkpoint_stall_seconds)),
                    ("sim_hours", Json::num(run.sim_hours)),
                    ("sampled_iterations", Json::num(run.sampled_iterations as f64)),
                    ("wall_ms", Json::num(wall_ms)),
                ]));
            }
        }
    }

    // Aggregate observed per-stage rate within [0.5, 1.5]× configured:
    // across ~1M stage-iterations the binomial noise is ≪ the band, so
    // the gate only trips on a genuinely wrong arrival process
    // (adjacency deferral trims a few percent at most).
    let rates_ok = agg_failures.iter().zip(&agg_stage_iters).all(|(&f, &si)| {
        let observed = f as f64 / si;
        observed > 0.5 * RATE_PER_STAGE && observed < 1.5 * RATE_PER_STAGE
    });

    println!("\ngates: matrix_complete={all_finite} event_driven_sparse={sparse_ok} rates_converge={rates_ok}");

    let out = Json::obj(vec![
        ("bench", Json::str("coverage")),
        ("schema", Json::num(1.0)),
        ("status", Json::str("measured")),
        (
            "generated_by",
            Json::str("cargo bench --bench coverage_matrix [-- --smoke]"),
        ),
        ("smoke", Json::Bool(smoke)),
        ("iterations_per_cell", Json::num(iterations as f64)),
        ("scales", Json::Arr(SCALES.iter().map(|&s| Json::num(s as f64)).collect())),
        (
            "strategies",
            Json::Arr(STRATEGIES.iter().map(|s| Json::str(s.label())).collect()),
        ),
        (
            "churn_processes",
            Json::Arr(ChurnProcessKind::ALL.iter().map(|c| Json::str(c.label())).collect()),
        ),
        ("cells", Json::Arr(cells)),
        (
            "gates",
            Json::obj(vec![
                ("gate_matrix_complete", Json::Bool(all_finite)),
                ("gate_event_driven_sparse", Json::Bool(sparse_ok)),
                ("gate_rates_converge", Json::Bool(rates_ok)),
            ]),
        ),
    ]);
    // Smoke runs go to the gitignored sidecar so quick runs never
    // clobber the committed trajectory.
    let path = if smoke {
        concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_coverage.smoke.json")
    } else {
        concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_coverage.json")
    };
    match std::fs::write(path, format!("{out}\n")) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
