//! Bench: the paper-scale **Table 2** simulator itself — full 12-cell
//! table regeneration plus the per-cell GPipe makespan kernel. Keeps the
//! table cheap enough to sweep (the ablation harnesses re-run it across
//! parameter grids).

use checkfree::config::Strategy;
use checkfree::netsim::Network;
use checkfree::sim::{
    gpipe_makespan, iteration_seconds, paper_converged_iterations, simulate_training, SimParams,
};
use checkfree::util::bench::bench;

fn main() {
    let stats = bench("gpipe_makespan 7 stages × 8 microbatches", || {
        let fwd = [1.0; 7];
        let bwd = [2.0; 7];
        let comm = [0.1; 6];
        std::hint::black_box(gpipe_makespan(&fwd, &bwd, &comm, 8));
    });
    println!("{}", stats.report());

    let p = SimParams::paper_medium(Strategy::CheckFree, 0.10);
    let net = Network::round_robin(p.stages);
    let stats = bench("iteration_seconds (steady-state model)", || {
        std::hint::black_box(iteration_seconds(&p, &net));
    });
    println!("{}", stats.report());

    let stats = bench("simulate_training 16k iterations @10%", || {
        std::hint::black_box(simulate_training(&p, 16_000));
    });
    println!("{}", stats.report());

    let stats = bench("full Table 2 (4 strategies × 3 rates)", || {
        for s in [
            Strategy::Checkpoint,
            Strategy::Redundant,
            Strategy::CheckFree,
            Strategy::CheckFreePlus,
        ] {
            for r in [0.05, 0.10, 0.16] {
                let p = SimParams::paper_medium(s, r);
                std::hint::black_box(simulate_training(&p, paper_converged_iterations(s, r)));
            }
        }
    });
    println!("{}", stats.report());
}
