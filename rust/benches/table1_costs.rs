//! Bench: recovery-operation micro-costs backing paper **Table 1**.
//!
//! Table 1 is analytic (printed by `checkfree costs`); this bench measures
//! the *actual* Rust-side cost of each strategy's recovery mechanism on a
//! live engine — weighted averaging vs copy vs random reinit vs full
//! snapshot/rollback — demonstrating that CheckFree's recovery work is
//! O(stage) with a small constant.

use checkfree::config::{default_artifacts_root, ReinitKind, Strategy, TrainConfig};
use checkfree::coordinator::PipelineEngine;
use checkfree::manifest::Manifest;
use checkfree::netsim::Network;
use checkfree::recovery::costs::render_table1;
use checkfree::recovery::{
    CheckFreeRecovery, CheckpointRecovery, RecoveryStrategy, RedundantRecovery,
};
use checkfree::util::bench::bench;

fn engine() -> PipelineEngine {
    let cfg = TrainConfig {
        model: "tiny".into(),
        strategy: Strategy::CheckFree,
        microbatches_per_iter: 2,
        ..TrainConfig::default()
    };
    let mut e = PipelineEngine::from_config(&cfg).unwrap();
    e.train_iteration().unwrap(); // populate ω
    e
}

fn main() {
    let manifest = Manifest::load_config(default_artifacts_root(), "tiny").unwrap();
    println!("{}", render_table1(&manifest));
    println!("--- measured recovery-op costs (tiny model, per event) ---");

    let mut e = engine();
    let net = Network::round_robin(e.stages.len());

    for reinit in [ReinitKind::WeightedAverage, ReinitKind::Copy, ReinitKind::Random] {
        let mut s = CheckFreeRecovery::new(reinit, 1.1, 0);
        let stats = bench(&format!("checkfree on_failure ({:?})", reinit), || {
            s.on_failure(&mut e, &net, 1).unwrap();
        });
        println!("{}", stats.report());
    }

    let mut ck = CheckpointRecovery::new(1);
    ck.after_iteration(&mut e, &net).unwrap();
    let stats = bench("checkpoint snapshot (after_iteration)", || {
        ck.after_iteration(&mut e, &net).unwrap();
    });
    println!("{}", stats.report());
    let stats = bench("checkpoint rollback (on_failure)", || {
        ck.on_failure(&mut e, &net, 1).unwrap();
    });
    println!("{}", stats.report());

    let mut rd = RedundantRecovery::new();
    let stats = bench("redundant on_failure (shadow takeover)", || {
        rd.after_iteration(&mut e, &net).unwrap();
        rd.on_failure(&mut e, &net, 1).unwrap();
    });
    println!("{}", stats.report());
}
