//! Bench: the L3 hot path — full training iterations through the PJRT
//! executables, sequential vs pipelined, plus the Rust-side pieces
//! (Adam, gradient accumulation, weighted-average recovery) in
//! isolation.
//!
//! This is the perf before/after harness for the concurrent fill/drain
//! executor: the `sequential` exec mode is the seed's reference
//! schedule, `pipelined` is the worker-thread executor, and the speedup
//! between them (≥4 microbatches so the pipe actually fills) is the
//! number the acceptance criteria track. Results are also written to
//! `BENCH_hot_path.json` at the repo root so future PRs can diff the
//! perf trajectory.
//!
//! Pass `--smoke` for a quick tiny-model-only run (used by
//! `scripts/tier1.sh` as the train_iteration timing check); smoke
//! results go to the gitignored `BENCH_hot_path.smoke.json` so they
//! never clobber the committed full-run trajectory.

use checkfree::config::{ExecMode, Strategy, TrainConfig};
use checkfree::coordinator::PipelineEngine;
use checkfree::model::GradBuffer;
use checkfree::recovery::checkfree::weighted_average;
use checkfree::runtime::HostTensor;
use checkfree::util::bench::{bench_with, fmt_dur};
use checkfree::util::json::Json;
use std::time::Duration;

const MICROBATCHES: usize = 4;

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let models: &[&str] = if smoke { &["tiny"] } else { &["tiny", "e2e"] };
    let iter_budget = Duration::from_secs(if smoke { 2 } else { 6 });

    let mut results: Vec<Json> = Vec::new();
    let mut speedups: Vec<(String, f64)> = Vec::new();

    'models: for &model in models {
        let mut mode_means: Vec<(ExecMode, f64)> = Vec::new();
        for mode in [ExecMode::Sequential, ExecMode::Pipelined] {
            let cfg = TrainConfig {
                model: model.into(),
                strategy: Strategy::CheckFree,
                microbatches_per_iter: MICROBATCHES,
                exec_mode: mode,
                ..TrainConfig::default()
            };
            let mut e = match PipelineEngine::from_config(&cfg) {
                Ok(e) => e,
                Err(err) => {
                    eprintln!("skipping {model}: {err:#}");
                    continue 'models;
                }
            };
            let stats = bench_with(
                &format!("train_iteration ({model}, {}, {MICROBATCHES} mb)", mode.label()),
                iter_budget,
                5,
                200,
                || {
                    e.train_iteration().unwrap();
                },
            );
            println!("{}", stats.report());
            let mut j = stats.to_json();
            if let Json::Obj(map) = &mut j {
                map.insert("model".into(), Json::str(model));
                map.insert("exec_mode".into(), Json::str(mode.label()));
                map.insert("microbatches".into(), Json::num(MICROBATCHES as f64));
            }
            results.push(j);
            mode_means.push((mode, stats.mean.as_secs_f64()));

            if mode == ExecMode::Pipelined {
                let stats = bench_with(
                    &format!("validate — 4 cache-served eval batches ({model})"),
                    Duration::from_secs(if smoke { 1 } else { 3 }),
                    5,
                    200,
                    || {
                        e.validate().unwrap();
                    },
                );
                println!("{}", stats.report());
                results.push(stats.to_json());

                // PJRT vs Rust-side split for the perf report.
                let exec = e.runtime.exec_stats();
                let total: f64 = exec.iter().map(|(_, d, _)| d.as_secs_f64()).sum();
                println!(
                    "  cumulative PJRT execute time this engine: {}",
                    fmt_dur(Duration::from_secs_f64(total))
                );
                for (name, d, calls) in &exec {
                    let share = if total > 0.0 { d.as_secs_f64() / total } else { 0.0 };
                    println!(
                        "    {name:<10} {:>10} over {calls:>6} calls ({:4.1}%)",
                        fmt_dur(*d),
                        share * 100.0
                    );
                    results.push(Json::obj(vec![
                        ("name", Json::str(format!("exec_share ({model}, {name})"))),
                        ("model", Json::str(model)),
                        ("executable", Json::str(name.clone())),
                        ("total_s", Json::num(d.as_secs_f64())),
                        ("calls", Json::num(*calls as f64)),
                        ("share", Json::num(share)),
                    ]));
                }
            }
        }
        if let (Some((_, seq)), Some((_, pipe))) = (
            mode_means.iter().find(|(m, _)| *m == ExecMode::Sequential),
            mode_means.iter().find(|(m, _)| *m == ExecMode::Pipelined),
        ) {
            let speedup = seq / pipe;
            println!("  {model}: pipelined speedup over sequential = {speedup:.2}×\n");
            speedups.push((model.to_string(), speedup));
        }
    }

    // Rust-side hot pieces in isolation (e2e body-stage sizes).
    let n = 1_600_000; // ≈ e2e body stage elements
    let host_budget = Duration::from_secs(if smoke { 1 } else { 2 });
    let a = vec![0.5f32; n];
    let g = vec![0.01f32; n];
    let mut adam = checkfree::model::Adam::new(&[n]);
    let mut p = a.clone();
    let stats = bench_with("adam update 1.6M params", host_budget, 5, 500, || {
        adam.update(&mut [&mut p], &[&g], 1e-3);
    });
    println!("{}", stats.report());
    results.push(stats.to_json());

    let mut gb = GradBuffer::new(&[n]);
    let gt = [HostTensor::from_f32_vec(vec![n], g.clone())];
    let stats = bench_with("grad accumulate 1.6M params", host_budget, 5, 500, || {
        gb.accumulate(&gt);
    });
    println!("{}", stats.report());
    results.push(stats.to_json());

    let ta = vec![HostTensor::from_f32_vec(vec![n], a.clone())];
    let tb = vec![HostTensor::from_f32_vec(vec![n], g.clone())];
    let stats = bench_with("weighted_average 1.6M params", host_budget, 5, 500, || {
        std::hint::black_box(weighted_average(&ta, &tb, 1.0, 2.0));
    });
    println!("{}", stats.report());
    results.push(stats.to_json());

    let out = Json::obj(vec![
        ("bench", Json::str("hot_path")),
        ("schema", Json::num(1.0)),
        ("status", Json::str("measured")),
        ("generated_by", Json::str("cargo bench --bench hot_path [-- --smoke]")),
        ("smoke", Json::Bool(smoke)),
        ("microbatches", Json::num(MICROBATCHES as f64)),
        (
            "pipelined_speedup",
            Json::Obj(
                speedups
                    .iter()
                    .map(|(m, s)| (m.clone(), Json::num(*s)))
                    .collect(),
            ),
        ),
        ("results", Json::Arr(results)),
    ]);
    // Smoke runs (tiny-only, short budgets) go to a sidecar file so they
    // never clobber the committed full-run perf trajectory.
    let path = if smoke {
        concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_hot_path.smoke.json")
    } else {
        concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_hot_path.json")
    };
    match std::fs::write(path, format!("{out}\n")) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
