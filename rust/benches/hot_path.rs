//! Bench: the L3 hot path — full training iterations through the PJRT
//! executables, plus the Rust-side pieces (Adam, gradient accumulation,
//! weighted-average recovery) in isolation. This is the §Perf
//! before/after harness: PJRT execute time should dominate (compute-
//! bound); if the Rust share grows, the coordinator has become the
//! bottleneck.

use checkfree::config::{Strategy, TrainConfig};
use checkfree::coordinator::PipelineEngine;
use checkfree::model::GradBuffer;
use checkfree::recovery::checkfree::weighted_average;
use checkfree::runtime::HostTensor;
use checkfree::util::bench::{bench_with, fmt_dur};
use std::time::Duration;

fn main() {
    for model in ["tiny", "e2e"] {
        let cfg = TrainConfig {
            model: model.into(),
            strategy: Strategy::CheckFree,
            microbatches_per_iter: 2,
            ..TrainConfig::default()
        };
        let mut e = match PipelineEngine::from_config(&cfg) {
            Ok(e) => e,
            Err(err) => {
                eprintln!("skipping {model}: {err:#}");
                continue;
            }
        };
        let stats = bench_with(
            &format!("train_iteration ({model}, 2 microbatches)"),
            Duration::from_secs(6),
            5,
            200,
            || {
                e.train_iteration().unwrap();
            },
        );
        println!("{}", stats.report());

        let batch = checkfree::data::BatchIter::validation_set(
            checkfree::data::Domain::Stories,
            1,
            1,
            e.runtime.manifest.config.microbatch,
            e.runtime.manifest.config.context,
            e.runtime.manifest.config.vocab,
        )
        .pop()
        .unwrap();
        let stats = bench_with(
            &format!("eval_loss forward-only ({model})"),
            Duration::from_secs(3),
            5,
            200,
            || {
                e.eval_loss(&batch).unwrap();
            },
        );
        println!("{}", stats.report());

        // PJRT vs Rust-side split for the perf report
        let total: f64 = e
            .runtime
            .exec_stats()
            .iter()
            .map(|(_, d, _)| d.as_secs_f64())
            .sum();
        println!("  cumulative PJRT execute time this process: {}", fmt_dur(Duration::from_secs_f64(total)));
    }

    // Rust-side hot pieces in isolation (e2e body-stage sizes)
    let n = 1_600_000; // ≈ e2e body stage elements
    let a = vec![0.5f32; n];
    let g = vec![0.01f32; n];
    let mut adam = checkfree::model::Adam::new(&[n]);
    let mut p = a.clone();
    let stats = bench_with("adam update 1.6M params", Duration::from_secs(2), 5, 500, || {
        adam.update(&mut [&mut p], &[&g], 1e-3);
    });
    println!("{}", stats.report());

    let mut gb = GradBuffer::new(&[n]);
    let gt = [HostTensor::from_f32_vec(vec![n], g.clone())];
    let stats = bench_with("grad accumulate 1.6M params", Duration::from_secs(2), 5, 500, || {
        gb.accumulate(&gt);
    });
    println!("{}", stats.report());

    let ta = vec![HostTensor::from_f32_vec(vec![n], a.clone())];
    let tb = vec![HostTensor::from_f32_vec(vec![n], g.clone())];
    let stats = bench_with("weighted_average 1.6M params", Duration::from_secs(2), 5, 500, || {
        std::hint::black_box(weighted_average(&ta, &tb, 1.0, 2.0));
    });
    println!("{}", stats.report());
}
