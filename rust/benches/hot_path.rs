//! Bench: the L3 hot path — full training iterations through the PJRT
//! executables across all three exec modes, plus the Rust-side pieces
//! (Adam, gradient accumulation, weighted-average recovery) in
//! isolation.
//!
//! This is the perf before/after harness for the concurrent executor:
//! `sequential` is the seed's reference schedule, `pipelined` the GPipe
//! fill/drain worker pool, `pipelined-1f1b` the 1F1B interleaved
//! schedule. The speedups over sequential (≥4 microbatches so the pipe
//! actually fills) are the numbers the acceptance criteria track, the
//! activation high-watermark section records peak resident activations
//! of both pipelined schedules at 8 microbatches — the 1F1B memory gate
//! — and the `device_residency` section records per-iteration host-sync
//! counts and bytes moved for the device-resident activation plane vs
//! the `--host-staging` baseline: the device gate requires 1F1B's
//! device-resident host syncs strictly below the host-staging path's
//! (see docs/BENCHMARKS.md). Since schema 2 the section also carries a
//! `pipelined-1f1b-per-stage` row (`--plane-mode per-stage`: one PJRT
//! client per stage) with the `link_copies`/`link_bytes` columns and a
//! parity gate — per-stage planes must keep host syncs identical to
//! the shared client (link copies are inter-device staging, not host
//! traffic). Schema 3 splits every link copy by path
//! (`link_direct`/`link_staged`) and counts `donated_buffers`, adding
//! two gates: same-process per-stage runs must record `link_staged ==
//! 0` (the direct fast path engages, no host round-trip per link), and
//! device-path donations must match the schedule (`m·(L+1)` dead
//! buffers handed to the runtime per iteration). The `plane_mode`
//! timing section records per-stage wall-clock under BOTH link paths,
//! so deployment policy can pick with the costs visible. Schema 4
//! splits every link copy by *when* it ran (`link_overlapped` — issued
//! ahead of the consumer by the sending worker — vs `link_blocking` —
//! performed in the consumer's call path) and meters `link_wait_ns`,
//! the consumer stall billed to the receiving stage; the `plane_mode`
//! section gains per-stage `link_wait_ns_overlap_on` /
//! `link_wait_ns_overlap_off` arrays and the
//! `gate_overlap_wait_below_off` gate — with prefetch on, every stage
//! that waits on links at all must wait strictly less than it does
//! with prefetch off. Schema 5 adds the `optimizer_path` section and
//! the `param_pulls` transfer column: a 1F1B iteration is timed with
//! the host Adam (every body gradient pulled, stepped on the host)
//! and with the fused on-plane Adam (`body_grad_accum` +
//! `body_adam`), and the device gate pins the ledger contract — the
//! device path's steady-state host syncs are exactly `m·4` (the
//! `m·L·P` gradient-pull term is gone), with zero `param_pulls`,
//! strictly below the host path's count. Schema 6 adds the
//! `transport` section and the `link_wire_bytes`/`link_wire_ns`
//! transfer columns: a steady-state per-stage 1F1B iteration's ledger
//! delta under `--link-transport in-process` vs `tcp-loopback` (gate:
//! the tcp row bills nonzero wire bytes strictly above its payload
//! bytes, the in-process row bills none), plus a `shaped` subsection
//! measuring each adjacent stage hop's emulated `gcp-5region` delay
//! against the netsim latency floor for its region pair (gate: no
//! measured link sits below its floor — `check_bench_json.py`
//! recomputes the floors independently). All previously committed
//! sections stay pinned to the host optimizer and the in-process
//! transport so the trajectory remains comparable. Results are
//! written to `BENCH_hot_path.json` at the repo root so future PRs
//! can diff the perf trajectory.
//!
//! Pass `--smoke` for a quick tiny-model-only run (used by
//! `scripts/tier1.sh` as the train_iteration timing check); smoke
//! results go to the gitignored `BENCH_hot_path.smoke.json` so they
//! never clobber the committed full-run trajectory.

use checkfree::config::{
    default_artifacts_root, ExecMode, LinkPath, LinkTransportKind, OptimizerPath, Overlap,
    PlaneMode, Strategy, TrainConfig, WanProfile,
};
use checkfree::coordinator::PipelineEngine;
use checkfree::metrics::TransferLedger;
use checkfree::model::GradBuffer;
use checkfree::netsim::Network;
use checkfree::recovery::checkfree::weighted_average;
use checkfree::runtime::{HostTensor, Runtime};
use checkfree::util::bench::{bench_with, fmt_dur};
use checkfree::util::json::Json;
use std::time::Duration;

const MICROBATCHES: usize = 4;
/// Microbatch count of the activation-watermark runs: ≥ 2× the tiny
/// pipeline depth, so fill/drain's O(m) stash visibly exceeds 1F1B's
/// depth bound.
const WATERMARK_MB: usize = 8;

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let models: &[&str] = if smoke { &["tiny"] } else { &["tiny", "e2e"] };
    let iter_budget = Duration::from_secs(if smoke { 2 } else { 6 });

    let mut results: Vec<Json> = Vec::new();
    let mut speedups: Vec<(String, f64)> = Vec::new();
    let mut speedups_1f1b: Vec<(String, f64)> = Vec::new();
    let mut watermarks: Vec<(String, Json)> = Vec::new();
    let mut residency: Vec<(String, Json)> = Vec::new();
    let mut plane_overheads: Vec<(String, Json)> = Vec::new();
    let mut opt_paths: Vec<(String, Json)> = Vec::new();
    let mut transports: Vec<(String, Json)> = Vec::new();

    'models: for &model in models {
        let mut mode_means: Vec<(ExecMode, f64)> = Vec::new();
        for mode in [ExecMode::Sequential, ExecMode::Pipelined, ExecMode::Pipelined1F1B] {
            // Plane mode and optimizer path pinned: the committed
            // speedup gates are defined over the shared client and host
            // Adam regardless of the ambient CHECKFREE_PLANE_MODE /
            // CHECKFREE_OPTIMIZER_PATH (the CI matrix levers); the
            // per-stage layout and the fused device optimizer are
            // measured separately below.
            let cfg = TrainConfig {
                model: model.into(),
                strategy: Strategy::CheckFree,
                microbatches_per_iter: MICROBATCHES,
                exec_mode: mode,
                plane_mode: PlaneMode::Shared,
                optimizer_path: OptimizerPath::Host,
                ..TrainConfig::default()
            };
            let mut e = match PipelineEngine::from_config(&cfg) {
                Ok(e) => e,
                Err(err) => {
                    eprintln!("skipping {model}: {err:#}");
                    continue 'models;
                }
            };
            let stats = bench_with(
                &format!("train_iteration ({model}, {}, {MICROBATCHES} mb)", mode.label()),
                iter_budget,
                5,
                200,
                || {
                    e.train_iteration().unwrap();
                },
            );
            println!("{}", stats.report());
            let mut j = stats.to_json();
            if let Json::Obj(map) = &mut j {
                map.insert("model".into(), Json::str(model));
                map.insert("exec_mode".into(), Json::str(mode.label()));
                map.insert("microbatches".into(), Json::num(MICROBATCHES as f64));
            }
            results.push(j);
            mode_means.push((mode, stats.mean.as_secs_f64()));

            if mode == ExecMode::Pipelined1F1B {
                let stats = bench_with(
                    &format!("validate — 4 cache-served eval batches ({model})"),
                    Duration::from_secs(if smoke { 1 } else { 3 }),
                    5,
                    200,
                    || {
                        e.validate().unwrap();
                    },
                );
                println!("{}", stats.report());
                results.push(stats.to_json());

                // PJRT vs Rust-side split for the perf report.
                let exec = e.runtime.exec_stats();
                let total: f64 = exec.iter().map(|(_, d, _)| d.as_secs_f64()).sum();
                println!(
                    "  cumulative PJRT execute time this engine: {}",
                    fmt_dur(Duration::from_secs_f64(total))
                );
                for (name, d, calls) in &exec {
                    let share = if total > 0.0 { d.as_secs_f64() / total } else { 0.0 };
                    println!(
                        "    {name:<10} {:>10} over {calls:>6} calls ({:4.1}%)",
                        fmt_dur(*d),
                        share * 100.0
                    );
                    results.push(Json::obj(vec![
                        ("name", Json::str(format!("exec_share ({model}, {name})"))),
                        ("model", Json::str(model)),
                        ("executable", Json::str(name.clone())),
                        ("total_s", Json::num(d.as_secs_f64())),
                        ("calls", Json::num(*calls as f64)),
                        ("share", Json::num(share)),
                    ]));
                }
            }
        }
        let mean_of = |mode: ExecMode| {
            mode_means.iter().find(|(m, _)| *m == mode).map(|&(_, s)| s)
        };
        if let (Some(seq), Some(pipe)) = (mean_of(ExecMode::Sequential), mean_of(ExecMode::Pipelined))
        {
            let speedup = seq / pipe;
            println!("  {model}: pipelined speedup over sequential = {speedup:.2}×");
            speedups.push((model.to_string(), speedup));
        }
        if let (Some(seq), Some(ob)) =
            (mean_of(ExecMode::Sequential), mean_of(ExecMode::Pipelined1F1B))
        {
            let speedup = seq / ob;
            println!("  {model}: 1F1B speedup over sequential = {speedup:.2}×\n");
            speedups_1f1b.push((model.to_string(), speedup));
        }

        // Activation high-watermark at WATERMARK_MB microbatches: the
        // 1F1B memory gate (peak must sit strictly below fill/drain's
        // L×m stash and within the Σ-warmup depth bound).
        let peak_of = |mode: ExecMode| -> Option<(usize, usize)> {
            let cfg = TrainConfig {
                model: model.into(),
                strategy: Strategy::CheckFree,
                microbatches_per_iter: WATERMARK_MB,
                exec_mode: mode,
                plane_mode: PlaneMode::Shared, // gate defined over the shared client
                optimizer_path: OptimizerPath::Host,
                ..TrainConfig::default()
            };
            let mut e = match PipelineEngine::from_config(&cfg) {
                Ok(e) => e,
                Err(err) => {
                    eprintln!("watermark run skipped ({model}, {}): {err:#}", mode.label());
                    return None;
                }
            };
            if let Err(err) = e.train_iteration() {
                eprintln!("watermark run failed ({model}, {}): {err:#}", mode.label());
                return None;
            }
            Some((e.peak_resident_activations(), e.body_stages()))
        };
        if let (Some((fd, l)), Some((ob, _))) =
            (peak_of(ExecMode::Pipelined), peak_of(ExecMode::Pipelined1F1B))
        {
            let depth_bound = l * (l + 1) / 2;
            println!(
                "  {model}: peak resident activations @ {WATERMARK_MB} mb — \
                 fill/drain {fd} (= {l}×{WATERMARK_MB}), 1F1B {ob} (bound {depth_bound})\n"
            );
            watermarks.push((
                model.to_string(),
                Json::obj(vec![
                    ("fill_drain", Json::num(fd as f64)),
                    ("one_f_one_b", Json::num(ob as f64)),
                    ("depth_bound", Json::num(depth_bound as f64)),
                    ("gate_1f1b_below_fill_drain", Json::Bool(ob < fd)),
                ]),
            ));
        }

        // Device residency: per-iteration transfer-ledger deltas of a
        // steady-state iteration (the 2nd — the 1st pays the first param
        // upload) for each mode, plus the host-staging baseline and the
        // per-stage-plane layout. Gates: device-resident 1F1B host syncs
        // strictly below host-staging's; per-stage host syncs EQUAL to
        // the shared client's (link copies are their own column); zero
        // STAGED link copies in the same-process per-stage run (the
        // direct fast path engages — pinned via an explicit Auto
        // policy, so an ambient CHECKFREE_LINK_PATH cannot skew the
        // committed gate); and donations matching the schedule.
        let transfers_of = |mode: ExecMode,
                            host_staging: bool,
                            plane_mode: PlaneMode,
                            optimizer_path: OptimizerPath|
         -> Option<(checkfree::metrics::TransferSnapshot, u64)> {
            let cfg = TrainConfig {
                model: model.into(),
                strategy: Strategy::CheckFree,
                microbatches_per_iter: MICROBATCHES,
                exec_mode: mode,
                host_staging,
                plane_mode,
                link_path: LinkPath::Auto,
                optimizer_path,
                ..TrainConfig::default()
            };
            let mut e = match PipelineEngine::from_config(&cfg) {
                Ok(e) => e,
                Err(err) => {
                    eprintln!("residency run skipped ({model}, {}): {err:#}", mode.label());
                    return None;
                }
            };
            if let Err(err) = e.train_iteration() {
                eprintln!("residency warmup failed ({model}, {}): {err:#}", mode.label());
                return None;
            }
            let before = e.transfer_ledger().snapshot();
            if let Err(err) = e.train_iteration() {
                eprintln!("residency run failed ({model}, {}): {err:#}", mode.label());
                return None;
            }
            Some((e.transfer_ledger().snapshot().since(&before), e.stages.len() as u64))
        };
        let transfers_json = |d: &checkfree::metrics::TransferSnapshot| {
            Json::obj(vec![
                ("host_syncs", Json::num(d.host_syncs as f64)),
                ("uploads", Json::num(d.uploads as f64)),
                ("bytes_down", Json::num(d.bytes_down as f64)),
                ("bytes_up", Json::num(d.bytes_up as f64)),
                ("forced_tuple_roundtrips", Json::num(d.forced_tuple_roundtrips as f64)),
                ("link_copies", Json::num(d.link_copies as f64)),
                ("link_bytes", Json::num(d.link_bytes as f64)),
                ("link_direct", Json::num(d.link_direct as f64)),
                ("link_staged", Json::num(d.link_staged as f64)),
                ("donated_buffers", Json::num(d.donated_buffers as f64)),
                ("link_overlapped", Json::num(d.link_overlapped as f64)),
                ("link_blocking", Json::num(d.link_blocking as f64)),
                ("link_wait_ns", Json::num(d.link_wait_ns as f64)),
                ("param_pulls", Json::num(d.param_pulls as f64)),
                ("link_wire_bytes", Json::num(d.link_wire_bytes as f64)),
                ("link_wire_ns", Json::num(d.link_wire_ns as f64)),
            ])
        };
        let host_opt = OptimizerPath::Host;
        let seq = transfers_of(ExecMode::Sequential, false, PlaneMode::Shared, host_opt);
        let fd = transfers_of(ExecMode::Pipelined, false, PlaneMode::Shared, host_opt);
        let ob = transfers_of(ExecMode::Pipelined1F1B, false, PlaneMode::Shared, host_opt);
        let ob_host = transfers_of(ExecMode::Pipelined1F1B, true, PlaneMode::Shared, host_opt);
        let ob_ps = transfers_of(ExecMode::Pipelined1F1B, false, PlaneMode::PerStage, host_opt);
        if let (Some(seq), Some(fd), Some(ob), Some(ob_host), Some(ob_ps)) =
            (seq, fd, ob, ob_host, ob_ps)
        {
            let (seq, _) = seq;
            let (fd, _) = fd;
            let (ob, stages) = ob;
            let (ob_host, _) = ob_host;
            let (ob_ps, _) = ob_ps;
            let want_donations = MICROBATCHES as u64 * stages;
            println!(
                "  {model}: host syncs/iter @ {MICROBATCHES} mb — sequential {}, \
                 fill/drain {}, 1F1B {}, 1F1B host-staging {} (gate: {} < {}); \
                 per-stage planes {} syncs + {} link copies ({} direct / {} staged; \
                 gates: {} == {}, staged == 0); donations {} (want {})\n",
                seq.host_syncs,
                fd.host_syncs,
                ob.host_syncs,
                ob_host.host_syncs,
                ob.host_syncs,
                ob_host.host_syncs,
                ob_ps.host_syncs,
                ob_ps.link_copies,
                ob_ps.link_direct,
                ob_ps.link_staged,
                ob_ps.host_syncs,
                ob.host_syncs,
                ob.donated_buffers,
                want_donations,
            );
            residency.push((
                model.to_string(),
                Json::obj(vec![
                    ("sequential", transfers_json(&seq)),
                    ("pipelined", transfers_json(&fd)),
                    ("pipelined-1f1b", transfers_json(&ob)),
                    ("pipelined-1f1b-host-staging", transfers_json(&ob_host)),
                    ("pipelined-1f1b-per-stage", transfers_json(&ob_ps)),
                    (
                        "gate_1f1b_device_syncs_below_host_staging",
                        Json::Bool(ob.host_syncs < ob_host.host_syncs),
                    ),
                    (
                        "gate_per_stage_syncs_equal_shared",
                        Json::Bool(ob_ps.host_syncs == ob.host_syncs),
                    ),
                    (
                        "gate_per_stage_staged_links_zero",
                        Json::Bool(
                            ob_ps.link_staged == 0
                                && ob_ps.link_direct == ob_ps.link_copies,
                        ),
                    ),
                    (
                        "gate_donations_match_schedule",
                        Json::Bool(
                            ob.donated_buffers == want_donations
                                && ob_ps.donated_buffers == want_donations
                                && ob_host.donated_buffers == 0,
                        ),
                    ),
                ]),
            ));
        }

        // Optimizer path: the schema-5 tentpole section. Times a 1F1B
        // iteration with the host Adam (every body gradient pulled and
        // stepped on the host) against the fused on-plane Adam
        // (`body_grad_accum` accumulates per-microbatch grads on the
        // owning stage's plane, `body_adam` steps there; host copies
        // materialize lazily at recovery/checkpoint boundaries). The
        // gate pins the ledger contract, not relative timing: device
        // steady-state host syncs are exactly m·4 — the m·L·P
        // gradient-pull term is deleted — with zero param pulls,
        // strictly below the host path's count. The host timing reuses
        // the 1F1B mean measured above (same model, shared-pinned,
        // host Adam).
        let dev_timed = {
            let cfg = TrainConfig {
                model: model.into(),
                strategy: Strategy::CheckFree,
                microbatches_per_iter: MICROBATCHES,
                exec_mode: ExecMode::Pipelined1F1B,
                plane_mode: PlaneMode::Shared,
                optimizer_path: OptimizerPath::Device,
                ..TrainConfig::default()
            };
            match PipelineEngine::from_config(&cfg) {
                Ok(mut e) => {
                    let stats = bench_with(
                        &format!("train_iteration ({model}, 1f1b, device optimizer)"),
                        Duration::from_secs(if smoke { 1 } else { 3 }),
                        5,
                        200,
                        || {
                            e.train_iteration().unwrap();
                        },
                    );
                    println!("{}", stats.report());
                    results.push(stats.to_json());
                    Some(stats.mean.as_secs_f64())
                }
                Err(err) => {
                    eprintln!("optimizer-path run skipped ({model}, device): {err:#}");
                    None
                }
            }
        };
        let host_t =
            transfers_of(ExecMode::Pipelined1F1B, false, PlaneMode::Shared, OptimizerPath::Host);
        let dev_t =
            transfers_of(ExecMode::Pipelined1F1B, false, PlaneMode::Shared, OptimizerPath::Device);
        if let (Some(host_s), Some(dev_s), Some((host_t, _)), Some((dev_t, _))) =
            (mean_of(ExecMode::Pipelined1F1B), dev_timed, host_t, dev_t)
        {
            let boundary_budget = MICROBATCHES as u64 * 4;
            let gate = dev_t.host_syncs == boundary_budget
                && dev_t.host_syncs < host_t.host_syncs
                && dev_t.param_pulls == 0;
            println!(
                "  {model}: optimizer path @ {MICROBATCHES} mb — host {} syncs/iter, \
                 device {} (budget m·4 = {boundary_budget}, param pulls {}); \
                 device over host wall-clock = {:.2}×  (gate m·4 ∧ below host ∧ \
                 zero pulls: {gate})\n",
                host_t.host_syncs,
                dev_t.host_syncs,
                dev_t.param_pulls,
                dev_s / host_s,
            );
            opt_paths.push((
                model.to_string(),
                Json::obj(vec![
                    ("host", transfers_json(&host_t)),
                    ("device", transfers_json(&dev_t)),
                    ("host_mean_s", Json::num(host_s)),
                    ("device_mean_s", Json::num(dev_s)),
                    ("device_over_host", Json::num(dev_s / host_s)),
                    ("gate_device_syncs_m4_below_host", Json::Bool(gate)),
                ]),
            ));
        }

        // Plane-mode wall-clock: what the per-stage link copies cost per
        // iteration under EACH link path — the direct plugin transfer
        // (the default fast path) and the staged device→host→device
        // baseline — so deployment policy can pick with the costs
        // visible (the Chameleon argument). Informative, not gated —
        // the parity + staged==0 gates above are the acceptance story.
        // The shared baseline reuses the 1F1B timing measured above
        // (same model, same microbatches, shared-pinned) instead of
        // paying a second multi-second run.
        let mut timed_per_stage = |link: LinkPath| -> Option<f64> {
            let cfg = TrainConfig {
                model: model.into(),
                strategy: Strategy::CheckFree,
                microbatches_per_iter: MICROBATCHES,
                exec_mode: ExecMode::Pipelined1F1B,
                plane_mode: PlaneMode::PerStage,
                link_path: link,
                optimizer_path: OptimizerPath::Host,
                ..TrainConfig::default()
            };
            let mut e = match PipelineEngine::from_config(&cfg) {
                Ok(e) => e,
                Err(err) => {
                    eprintln!(
                        "plane-mode run skipped ({model}, per-stage, {}): {err:#}",
                        link.label()
                    );
                    return None;
                }
            };
            let stats = bench_with(
                &format!("train_iteration ({model}, 1f1b, per-stage, {} links)", link.label()),
                Duration::from_secs(if smoke { 1 } else { 3 }),
                5,
                200,
                || {
                    e.train_iteration().unwrap();
                },
            );
            println!("{}", stats.report());
            results.push(stats.to_json());
            Some(stats.mean.as_secs_f64())
        };
        let shared_s = mean_of(ExecMode::Pipelined1F1B);
        let direct_s = timed_per_stage(LinkPath::Direct);
        // The staged run is only a comparison point for the direct one:
        // skip its multi-second budget when the direct leg already
        // failed (e.g. a plugin without cross-client transfer).
        let staged_s = if direct_s.is_some() {
            timed_per_stage(LinkPath::Staged)
        } else {
            None
        };
        // Per-stage consumer link wait with prefetch on vs off: the
        // schema-4 overlap gate. Same steady-state-iteration protocol
        // as the residency ledger (2nd iteration delta), per-stage so
        // the wait lands where it is billed — the receiving stage.
        let stage_link_waits = |overlap: Overlap| -> Option<Vec<u64>> {
            let cfg = TrainConfig {
                model: model.into(),
                strategy: Strategy::CheckFree,
                microbatches_per_iter: MICROBATCHES,
                exec_mode: ExecMode::Pipelined1F1B,
                plane_mode: PlaneMode::PerStage,
                link_path: LinkPath::Auto,
                overlap,
                optimizer_path: OptimizerPath::Host,
                ..TrainConfig::default()
            };
            let mut e = match PipelineEngine::from_config(&cfg) {
                Ok(e) => e,
                Err(err) => {
                    eprintln!("overlap run skipped ({model}, {}): {err:#}", overlap.label());
                    return None;
                }
            };
            if let Err(err) = e.train_iteration() {
                eprintln!("overlap warmup failed ({model}, {}): {err:#}", overlap.label());
                return None;
            }
            let before: Vec<_> = {
                let ledger = e.transfer_ledger();
                (0..ledger.stage_count()).map(|i| ledger.stage_snapshot(i)).collect()
            };
            if let Err(err) = e.train_iteration() {
                eprintln!("overlap run failed ({model}, {}): {err:#}", overlap.label());
                return None;
            }
            let ledger = e.transfer_ledger();
            Some(
                (0..ledger.stage_count())
                    .map(|i| ledger.stage_snapshot(i).since(&before[i]).link_wait_ns)
                    .collect(),
            )
        };
        let wait_on = stage_link_waits(Overlap::On);
        let wait_off = stage_link_waits(Overlap::Off);

        if let (Some(shared_s), Some(direct_s), Some(staged_s)) = (shared_s, direct_s, staged_s) {
            let overhead = direct_s / shared_s;
            let direct_vs_staged = direct_s / staged_s;
            println!(
                "  {model}: per-stage (direct links) over shared = {overhead:.2}×; \
                 direct over staged = {direct_vs_staged:.2}×"
            );
            let mut fields = vec![
                ("shared_mean_s", Json::num(shared_s)),
                ("per_stage_mean_s", Json::num(direct_s)),
                ("per_stage_staged_mean_s", Json::num(staged_s)),
                ("per_stage_over_shared", Json::num(overhead)),
                ("direct_over_staged", Json::num(direct_vs_staged)),
            ];
            if let (Some(on), Some(off)) = (&wait_on, &wait_off) {
                // Gate: every stage that waits on links at all (off > 0)
                // must wait strictly less with prefetch on; vacuous
                // (all-zero) runs fail the gate rather than pass it.
                let gate = off.iter().any(|&w| w > 0)
                    && on.iter().zip(off.iter()).all(|(&a, &b)| b == 0 || a < b);
                println!(
                    "  {model}: per-stage link wait ns — overlap on {on:?} vs off {off:?} \
                     (gate on < off per stage: {gate})\n"
                );
                let arr = |v: &[u64]| Json::Arr(v.iter().map(|&w| Json::num(w as f64)).collect());
                fields.push(("link_wait_ns_overlap_on", arr(on)));
                fields.push(("link_wait_ns_overlap_off", arr(off)));
                fields.push(("gate_overlap_wait_below_off", Json::Bool(gate)));
            } else {
                println!();
            }
            plane_overheads.push((model.to_string(), Json::obj(fields)));
        }

        // Wire transport: the schema-6 section. Same steady-state
        // protocol as the residency ledger (2nd-iteration delta),
        // per-stage 1F1B, once per link transport. The tcp-loopback
        // row must bill the new wire columns — frames strictly larger
        // than the payloads they carry (CFW1 header overhead) with
        // nonzero wire time, every wire hop landing in the staged
        // split — while the in-process row bills none; both keep the
        // overlap invariant. `check_bench_json.py` hard-fails a
        // measured tcp row with zero wire bytes.
        let transport_transfers =
            |kind: LinkTransportKind| -> Option<checkfree::metrics::TransferSnapshot> {
                let cfg = TrainConfig {
                    model: model.into(),
                    strategy: Strategy::CheckFree,
                    microbatches_per_iter: MICROBATCHES,
                    exec_mode: ExecMode::Pipelined1F1B,
                    plane_mode: PlaneMode::PerStage,
                    link_path: LinkPath::Auto,
                    link_transport: kind,
                    optimizer_path: OptimizerPath::Host,
                    ..TrainConfig::default()
                };
                let mut e = match PipelineEngine::from_config(&cfg) {
                    Ok(e) => e,
                    Err(err) => {
                        eprintln!("transport run skipped ({model}, {}): {err:#}", kind.label());
                        return None;
                    }
                };
                if let Err(err) = e.train_iteration() {
                    eprintln!("transport warmup failed ({model}, {}): {err:#}", kind.label());
                    return None;
                }
                let before = e.transfer_ledger().snapshot();
                if let Err(err) = e.train_iteration() {
                    eprintln!("transport run failed ({model}, {}): {err:#}", kind.label());
                    return None;
                }
                Some(e.transfer_ledger().snapshot().since(&before))
            };
        // WAN shaping: one measured hop per adjacent stage pair under
        // the gcp-5region profile (Shaped over the in-process
        // transport, so the emulated delay is the only wire cost and
        // link_wire_ns is exactly that delay). Each row carries the
        // netsim floor — scale × one-way latency for its region pair,
        // i.e. the zero-byte transfer time — and the gate is that no
        // measured link undercuts its floor. `check_bench_json.py`
        // recomputes the floors from its own copy of the latency
        // matrix and hard-fails any row sitting below.
        let shaped_links = |scale: f64| -> Option<Vec<(&'static str, &'static str, u64, u64)>> {
            let rt = match Runtime::load_config_wire(
                default_artifacts_root(),
                model,
                PlaneMode::PerStage,
                LinkPath::Auto,
                LinkTransportKind::InProcess,
                WanProfile::Gcp5Region,
                scale,
            ) {
                Ok(rt) => rt,
                Err(err) => {
                    eprintln!("shaped run skipped ({model}): {err:#}");
                    return None;
                }
            };
            let planes = rt.plane_count();
            let net = Network::blocked(planes);
            let ledger = TransferLedger::new(planes);
            let set = rt.plane_set(&ledger);
            let mut rows = Vec::with_capacity(planes.saturating_sub(1));
            for src in 0..planes.saturating_sub(1) {
                let dst = src + 1;
                let t = HostTensor::from_f32_vec(vec![2], vec![1.0, -1.0]);
                let d = match set.plane(src).upload(src, &t) {
                    Ok(d) => d,
                    Err(err) => {
                        eprintln!("shaped upload failed ({model}, stage {src}): {err:#}");
                        return None;
                    }
                };
                let before = ledger.stage_snapshot(dst).link_wire_ns;
                if let Err(err) = d.copy_to_plane(set.plane(dst), dst) {
                    eprintln!("shaped hop failed ({model}, {src}→{dst}): {err:#}");
                    return None;
                }
                let wire_ns = ledger.stage_snapshot(dst).link_wire_ns - before;
                let (a, b) = match (net.region_of(src), net.region_of(dst)) {
                    (Ok(a), Ok(b)) => (a, b),
                    _ => return None,
                };
                let floor_ns = (scale * net.transfer_seconds_between(0, a, b) * 1e9) as u64;
                rows.push((a.label(), b.label(), wire_ns, floor_ns));
            }
            Some(rows)
        };
        let inproc_t = transport_transfers(LinkTransportKind::InProcess);
        let tcp_t = transport_transfers(LinkTransportKind::TcpLoopback);
        // Keep the shaped rows cheap: real gcp one-way latencies are
        // hundreds of ms, so scale the emulation down — the floor
        // scales with it, which is exactly what the gate checks.
        let wan_scale = 1e-3;
        let shaped_rows = shaped_links(wan_scale);
        if let (Some(ip), Some(tcp)) = (inproc_t, tcp_t) {
            let gate_wire = tcp.link_wire_bytes > tcp.link_bytes
                && tcp.link_wire_ns > 0
                && tcp.link_staged == tcp.link_copies
                && ip.link_wire_bytes == 0
                && ip.link_wire_ns == 0
                && ip.link_overlapped + ip.link_blocking == ip.link_copies
                && tcp.link_overlapped + tcp.link_blocking == tcp.link_copies;
            println!(
                "  {model}: transport @ {MICROBATCHES} mb — in-process {} link copies \
                 ({} wire bytes), tcp-loopback {} copies ({} wire bytes / {} payload \
                 bytes, {} wire ns; gate frames > payload ∧ staged ∧ invariant: \
                 {gate_wire})",
                ip.link_copies,
                ip.link_wire_bytes,
                tcp.link_copies,
                tcp.link_wire_bytes,
                tcp.link_bytes,
                tcp.link_wire_ns,
            );
            let mut fields = vec![
                ("in-process", transfers_json(&ip)),
                ("tcp-loopback", transfers_json(&tcp)),
                ("gate_tcp_wire_billed", Json::Bool(gate_wire)),
            ];
            if let Some(rows) = shaped_rows {
                let gate_floor =
                    !rows.is_empty() && rows.iter().all(|&(_, _, mean, floor)| mean >= floor);
                println!(
                    "  {model}: shaped gcp-5region @ scale {wan_scale} — {} adjacent \
                     links (gate every mean ≥ floor: {gate_floor})\n",
                    rows.len(),
                );
                let links = rows
                    .iter()
                    .map(|&(src, dst, mean, floor)| {
                        Json::obj(vec![
                            ("src_region", Json::str(src)),
                            ("dst_region", Json::str(dst)),
                            ("mean_link_ns", Json::num(mean as f64)),
                            ("floor_ns", Json::num(floor as f64)),
                        ])
                    })
                    .collect();
                fields.push((
                    "shaped",
                    Json::obj(vec![
                        ("profile", Json::str(WanProfile::Gcp5Region.label())),
                        ("scale", Json::num(wan_scale)),
                        ("links", Json::Arr(links)),
                        ("gate_shaped_above_floor", Json::Bool(gate_floor)),
                    ]),
                ));
            } else {
                println!();
            }
            transports.push((model.to_string(), Json::obj(fields)));
        }
    }

    // Rust-side hot pieces in isolation (e2e body-stage sizes).
    let n = 1_600_000; // ≈ e2e body stage elements
    let host_budget = Duration::from_secs(if smoke { 1 } else { 2 });
    let a = vec![0.5f32; n];
    let g = vec![0.01f32; n];
    let mut adam = checkfree::model::Adam::new(&[n]);
    let mut p = a.clone();
    let stats = bench_with("adam update 1.6M params", host_budget, 5, 500, || {
        adam.update(&mut [&mut p], &[&g], 1e-3);
    });
    println!("{}", stats.report());
    results.push(stats.to_json());

    let mut gb = GradBuffer::new(&[n]);
    let gt = [HostTensor::from_f32_vec(vec![n], g.clone())];
    let stats = bench_with("grad accumulate 1.6M params", host_budget, 5, 500, || {
        gb.accumulate(&gt);
    });
    println!("{}", stats.report());
    results.push(stats.to_json());

    let ta = vec![HostTensor::from_f32_vec(vec![n], a.clone())];
    let tb = vec![HostTensor::from_f32_vec(vec![n], g.clone())];
    let stats = bench_with("weighted_average 1.6M params", host_budget, 5, 500, || {
        std::hint::black_box(weighted_average(&ta, &tb, 1.0, 2.0));
    });
    println!("{}", stats.report());
    results.push(stats.to_json());

    let out = Json::obj(vec![
        ("bench", Json::str("hot_path")),
        ("schema", Json::num(6.0)),
        ("status", Json::str("measured")),
        ("generated_by", Json::str("cargo bench --bench hot_path [-- --smoke]")),
        ("smoke", Json::Bool(smoke)),
        ("microbatches", Json::num(MICROBATCHES as f64)),
        (
            "pipelined_speedup",
            Json::Obj(
                speedups
                    .iter()
                    .map(|(m, s)| (m.clone(), Json::num(*s)))
                    .collect(),
            ),
        ),
        (
            "pipelined_1f1b_speedup",
            Json::Obj(
                speedups_1f1b
                    .iter()
                    .map(|(m, s)| (m.clone(), Json::num(*s)))
                    .collect(),
            ),
        ),
        (
            "activation_watermark",
            Json::obj(
                std::iter::once(("microbatches", Json::num(WATERMARK_MB as f64)))
                    .chain(watermarks.iter().map(|(m, j)| (m.as_str(), j.clone())))
                    .collect(),
            ),
        ),
        (
            "device_residency",
            Json::obj(
                std::iter::once(("microbatches", Json::num(MICROBATCHES as f64)))
                    .chain(residency.iter().map(|(m, j)| (m.as_str(), j.clone())))
                    .collect(),
            ),
        ),
        (
            "plane_mode",
            Json::obj(
                plane_overheads.iter().map(|(m, j)| (m.as_str(), j.clone())).collect(),
            ),
        ),
        (
            "optimizer_path",
            Json::obj(
                std::iter::once(("microbatches", Json::num(MICROBATCHES as f64)))
                    .chain(opt_paths.iter().map(|(m, j)| (m.as_str(), j.clone())))
                    .collect(),
            ),
        ),
        (
            "transport",
            Json::obj(
                std::iter::once(("microbatches", Json::num(MICROBATCHES as f64)))
                    .chain(transports.iter().map(|(m, j)| (m.as_str(), j.clone())))
                    .collect(),
            ),
        ),
        ("results", Json::Arr(results)),
    ]);
    // Smoke runs (tiny-only, short budgets) go to a sidecar file so they
    // never clobber the committed full-run perf trajectory.
    let path = if smoke {
        concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_hot_path.smoke.json")
    } else {
        concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_hot_path.json")
    };
    match std::fs::write(path, format!("{out}\n")) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
