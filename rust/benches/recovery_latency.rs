//! Bench: simulated recovery latency across model scales and stages —
//! backs the paper's §5.1 claim that CheckFree stage recovery takes
//! ≈30 s at the 500M scale, and shows how it scales with stage size and
//! placement vs checkpoint-download recovery.
//!
//! Emits `BENCH_recovery.json` at the repo root (simulated latencies +
//! netsim micro-bench stats) so the perf trajectory is diffable across
//! PRs.
//!
//! Pass `--smoke` for the CI recovery-smoke lane: short micro-bench
//! budgets, results written to the **gitignored**
//! `BENCH_recovery.smoke.json` sidecar (uploaded as a workflow
//! artifact) so quick runs never clobber the committed trajectory. The
//! simulated latencies are closed-form either way — only the
//! micro-bench sampling budget differs.

use std::time::Duration;

use checkfree::netsim::{Network, Region};
use checkfree::util::bench::bench_with;
use checkfree::util::json::Json;

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let micro_budget = Duration::from_secs(if smoke { 1 } else { 3 });
    let mut latencies: Vec<Json> = Vec::new();
    let mut micro: Vec<Json> = Vec::new();

    println!("--- simulated recovery latencies (netsim) ---");
    let scales: [(&str, u64, u64); 3] = [
        ("small-124M (4+1 stages)", 124_000_000 / 4 * 4, 124_000_000 * 4),
        ("medium-500M (6+1 stages)", 333_000_000, 2_000_000_000),
        ("large-1.5B (6+1 stages)", 1_000_000_000, 6_000_000_000),
    ];
    for (label, stage_bytes, model_bytes) in scales {
        let stages = if label.starts_with("small") { 5 } else { 7 };
        let net = Network::round_robin(stages);
        let cf: f64 = (1..stages)
            .map(|s| net.checkfree_recovery_seconds(stage_bytes, s).unwrap())
            .fold(0.0, f64::max);
        let ck_down = net.storage_transfer_seconds(stage_bytes);
        let ck_up = net.storage_transfer_seconds(model_bytes);
        println!(
            "{label:<28} checkfree {cf:>7.1}s | ckpt download {ck_down:>7.1}s | ckpt upload {ck_up:>8.1}s"
        );
        latencies.push(Json::obj(vec![
            ("scale", Json::str(label)),
            ("stage_bytes", Json::num(stage_bytes as f64)),
            ("model_bytes", Json::num(model_bytes as f64)),
            ("checkfree_worst_s", Json::num(cf)),
            ("ckpt_download_s", Json::num(ck_down)),
            ("ckpt_upload_s", Json::num(ck_up)),
        ]));
    }

    println!("\n--- netsim micro-benchmarks ---");
    let net = Network::round_robin(7);
    let stats = bench_with("transfer_seconds (single edge)", micro_budget, 5, 500, || {
        std::hint::black_box(net.transfer_seconds(333_000_000, 2, 3).unwrap());
    });
    println!("{}", stats.report());
    micro.push(stats.to_json());
    let stats = bench_with(
        "checkfree_recovery_seconds (both neighbours)",
        micro_budget,
        5,
        500,
        || {
            std::hint::black_box(net.checkfree_recovery_seconds(333_000_000, 3).unwrap());
        },
    );
    println!("{}", stats.report());
    micro.push(stats.to_json());
    let single = Network::single_region(7, Region::UsCentral);
    let stats = bench_with("recovery in single-region cluster", micro_budget, 5, 500, || {
        std::hint::black_box(single.checkfree_recovery_seconds(333_000_000, 3).unwrap());
    });
    println!("{}", stats.report());
    micro.push(stats.to_json());

    let out = Json::obj(vec![
        ("bench", Json::str("recovery")),
        ("schema", Json::num(1.0)),
        ("status", Json::str("measured")),
        ("generated_by", Json::str("cargo bench --bench recovery_latency [-- --smoke]")),
        ("smoke", Json::Bool(smoke)),
        ("simulated_latencies", Json::Arr(latencies)),
        ("microbench", Json::Arr(micro)),
    ]);
    // Smoke runs (short budgets) go to the gitignored sidecar so CI's
    // recovery-smoke lane never clobbers the committed trajectory.
    let path = if smoke {
        concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_recovery.smoke.json")
    } else {
        concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_recovery.json")
    };
    match std::fs::write(path, format!("{out}\n")) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
