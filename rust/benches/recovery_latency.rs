//! Bench: simulated recovery latency across model scales and stages —
//! backs the paper's §5.1 claim that CheckFree stage recovery takes
//! ≈30 s at the 500M scale, and shows how it scales with stage size and
//! placement vs checkpoint-download recovery.
//!
//! Emits `BENCH_recovery.json` at the repo root (simulated latencies +
//! netsim micro-bench stats + the policy-gate tape replay) so the perf
//! trajectory is diffable across PRs.
//!
//! Schema 2 adds the `policy` section: every strategy replayed over the
//! committed `examples/traces/burst_storm.jsonl` tape via
//! `sim::simulate_tape`, with two gates `scripts/check_bench_json.py`
//! enforces — the adaptive policy strictly beats every static strategy
//! on convergence wall-clock, and the tiercheck restore path moves zero
//! storage bytes.
//!
//! Pass `--smoke` for the CI recovery-smoke lane: short micro-bench
//! budgets, results written to the **gitignored**
//! `BENCH_recovery.smoke.json` sidecar (uploaded as a workflow
//! artifact) so quick runs never clobber the committed trajectory. The
//! simulated latencies are closed-form either way — only the
//! micro-bench sampling budget differs.

use std::time::Duration;

use checkfree::config::{AdaptiveThresholds, Strategy};
use checkfree::failures::ChurnTrace;
use checkfree::netsim::{Network, Region};
use checkfree::sim::{simulate_tape, SimParams};
use checkfree::util::bench::bench_with;
use checkfree::util::json::Json;

/// Replay the committed policy-gate tape under every strategy and emit
/// the `policy` section the external checker gates on.
fn policy_section() -> Json {
    let tape_path =
        concat!(env!("CARGO_MANIFEST_DIR"), "/../examples/traces/burst_storm.jsonl");
    let tape = ChurnTrace::read_file(tape_path).expect("committed gate tape must load");
    let iterations = 600u64;
    println!("\n--- policy gate: burst_storm tape replay ({iterations} iters) ---");
    let mut walls: Vec<(Strategy, f64)> = Vec::new();
    let mut runs: Vec<Json> = Vec::new();
    let mut adaptive_switches: Vec<Json> = Vec::new();
    let mut tier_restore_storage = 0u64;
    for s in Strategy::ALL {
        if s == Strategy::None {
            continue; // dies on the first event; not a comparable baseline
        }
        let run = simulate_tape(
            &SimParams::policy_gate(s),
            &tape,
            iterations,
            AdaptiveThresholds::default(),
        );
        println!(
            "{:<12} wall {:>10.1}s  rollback {:>4} it  extra {:>5.1} it  storage {:>12} B",
            s.label(),
            run.wall_clock_s,
            run.rollback_iterations,
            run.extra_convergence_iterations,
            run.storage_bytes
        );
        if s == Strategy::Adaptive {
            adaptive_switches =
                run.switch_iterations.iter().map(|&i| Json::num(i as f64)).collect();
        }
        if s == Strategy::TierCheck {
            tier_restore_storage = run.restore_storage_bytes;
        }
        walls.push((s, run.wall_clock_s));
        runs.push(Json::obj(vec![
            ("strategy", Json::str(s.label())),
            ("wall_clock_s", Json::num(run.wall_clock_s)),
            ("failures", Json::num(run.failures as f64)),
            ("rollback_iterations", Json::num(run.rollback_iterations as f64)),
            ("extra_convergence_iterations", Json::num(run.extra_convergence_iterations)),
            ("storage_bytes", Json::num(run.storage_bytes as f64)),
            ("tier_backup_bytes", Json::num(run.tier_backup_bytes as f64)),
            ("restore_storage_bytes", Json::num(run.restore_storage_bytes as f64)),
        ]));
    }
    let adaptive_wall =
        walls.iter().find(|(s, _)| *s == Strategy::Adaptive).map(|(_, w)| *w).unwrap();
    let beats_static = walls
        .iter()
        .filter(|(s, _)| *s != Strategy::Adaptive)
        .all(|(_, w)| adaptive_wall < *w);
    Json::obj(vec![
        ("tape", Json::str("examples/traces/burst_storm.jsonl")),
        ("iterations", Json::num(iterations as f64)),
        ("runs", Json::Arr(runs)),
        ("adaptive_switch_iterations", Json::Arr(adaptive_switches)),
        ("tiercheck_restore_storage_bytes", Json::num(tier_restore_storage as f64)),
        ("gate_adaptive_beats_static", Json::Bool(beats_static)),
        ("gate_tiercheck_zero_storage_bytes", Json::Bool(tier_restore_storage == 0)),
    ])
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let micro_budget = Duration::from_secs(if smoke { 1 } else { 3 });
    let mut latencies: Vec<Json> = Vec::new();
    let mut micro: Vec<Json> = Vec::new();

    println!("--- simulated recovery latencies (netsim) ---");
    let scales: [(&str, u64, u64); 3] = [
        ("small-124M (4+1 stages)", 124_000_000 / 4 * 4, 124_000_000 * 4),
        ("medium-500M (6+1 stages)", 333_000_000, 2_000_000_000),
        ("large-1.5B (6+1 stages)", 1_000_000_000, 6_000_000_000),
    ];
    for (label, stage_bytes, model_bytes) in scales {
        let stages = if label.starts_with("small") { 5 } else { 7 };
        let net = Network::round_robin(stages);
        let cf: f64 = (1..stages)
            .map(|s| net.checkfree_recovery_seconds(stage_bytes, s).unwrap())
            .fold(0.0, f64::max);
        let ck_down = net.storage_transfer_seconds(stage_bytes);
        let ck_up = net.storage_transfer_seconds(model_bytes);
        println!(
            "{label:<28} checkfree {cf:>7.1}s | ckpt download {ck_down:>7.1}s | ckpt upload {ck_up:>8.1}s"
        );
        latencies.push(Json::obj(vec![
            ("scale", Json::str(label)),
            ("stage_bytes", Json::num(stage_bytes as f64)),
            ("model_bytes", Json::num(model_bytes as f64)),
            ("checkfree_worst_s", Json::num(cf)),
            ("ckpt_download_s", Json::num(ck_down)),
            ("ckpt_upload_s", Json::num(ck_up)),
        ]));
    }

    println!("\n--- netsim micro-benchmarks ---");
    let net = Network::round_robin(7);
    let stats = bench_with("transfer_seconds (single edge)", micro_budget, 5, 500, || {
        std::hint::black_box(net.transfer_seconds(333_000_000, 2, 3).unwrap());
    });
    println!("{}", stats.report());
    micro.push(stats.to_json());
    let stats = bench_with(
        "checkfree_recovery_seconds (both neighbours)",
        micro_budget,
        5,
        500,
        || {
            std::hint::black_box(net.checkfree_recovery_seconds(333_000_000, 3).unwrap());
        },
    );
    println!("{}", stats.report());
    micro.push(stats.to_json());
    let single = Network::single_region(7, Region::UsCentral);
    let stats = bench_with("recovery in single-region cluster", micro_budget, 5, 500, || {
        std::hint::black_box(single.checkfree_recovery_seconds(333_000_000, 3).unwrap());
    });
    println!("{}", stats.report());
    micro.push(stats.to_json());

    let policy = policy_section();

    let out = Json::obj(vec![
        ("bench", Json::str("recovery")),
        ("schema", Json::num(2.0)),
        ("status", Json::str("measured")),
        ("generated_by", Json::str("cargo bench --bench recovery_latency [-- --smoke]")),
        ("smoke", Json::Bool(smoke)),
        ("simulated_latencies", Json::Arr(latencies)),
        ("microbench", Json::Arr(micro)),
        ("policy", policy),
    ]);
    // Smoke runs (short budgets) go to the gitignored sidecar so CI's
    // recovery-smoke lane never clobbers the committed trajectory.
    let path = if smoke {
        concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_recovery.smoke.json")
    } else {
        concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_recovery.json")
    };
    match std::fs::write(path, format!("{out}\n")) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
