"""L1/L2 performance analysis: HLO cost model + kernel VMEM/MXU estimates.

This backs EXPERIMENTS.md §Perf. Interpret-mode Pallas gives CPU-numpy
timings only, so L1 is profiled *structurally*: VMEM footprint per grid
cell and MXU-tile alignment from the BlockSpecs. L2 is profiled through
XLA's own cost analysis on the lowered HLO modules (flops, bytes
accessed, arithmetic intensity), which is hardware-independent.

Usage::

    cd python && python -m compile.perf --configs tiny,e2e
"""

from __future__ import annotations

import argparse

import jax
from jax._src.lib import xla_client as xc

from .kernels.attention import DEFAULT_BLOCK_K, DEFAULT_BLOCK_Q, vmem_bytes_estimate
from .model import PRESETS, make_entry_points


def hlo_cost(fn, specs) -> dict:
    """XLA cost analysis of a lowered entry point."""
    lowered = jax.jit(fn, keep_unused=True).lower(*specs)
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(lowered.compiler_ir("stablehlo")), use_tuple_args=False, return_tuple=True
    )
    mod = xc._xla.hlo_module_from_text(comp.as_hlo_text())
    client = jax.devices()[0].client
    return xc._xla.hlo_module_cost_analysis(client, mod)


def mxu_alignment(dim: int, tile: int = 128) -> float:
    """Fraction of the contracted dim covered by full MXU tiles."""
    if dim >= tile:
        return (dim // tile * tile) / dim
    return dim / tile


def report(config_names: list[str]) -> None:
    for name in config_names:
        cfg = PRESETS[name]
        print(f"\n=== config '{name}' ({cfg.param_count() / 1e6:.1f}M params) ===")

        # ---- L1: attention kernel structure ----
        s, dh = cfg.context, cfg.head_dim
        vmem = vmem_bytes_estimate(s, dh)
        print(
            f"L1 attention: blocks q={min(DEFAULT_BLOCK_Q, s)} k={min(DEFAULT_BLOCK_K, s)}, "
            f"VMEM/cell {vmem / 1024:.1f} KiB "
            f"({'fits' if vmem < 16 * 2**20 else 'EXCEEDS'} 16 MiB core budget)"
        )
        print(
            f"L1 MXU tile alignment: head_dim {dh} → {mxu_alignment(dh):.2f}, "
            f"ffn {cfg.ffn} → {mxu_alignment(cfg.ffn):.2f}, "
            f"dim {cfg.dim} → {mxu_alignment(cfg.dim):.2f} (1.0 = fully aligned)"
        )
        # causal skip halves visited KV tiles
        print("L1 causal tile skip: ~2x work saving vs dense (kb_hi bound)")

        # ---- L2: HLO cost per entry point ----
        entries = make_entry_points(cfg)
        tokens = cfg.microbatch * cfg.context
        print(f"L2 HLO cost analysis (per microbatch of {tokens} tokens):")
        total_flops = 0.0
        for ename, (fn, specs) in entries.items():
            c = hlo_cost(fn, specs)
            flops = c.get("flops", 0.0)
            bytes_ = c.get("bytes accessed", 0.0)
            inten = flops / bytes_ if bytes_ else 0.0
            # body entry points execute once PER BODY STAGE each microbatch
            mult = cfg.body_stages if ename.startswith("body") else 1
            total_flops += flops * mult
            print(
                f"  {ename:<10} {flops / 1e6:>10.1f} MFLOP {bytes_ / 2**20:>9.1f} MiB"
                f"  intensity {inten:>6.2f} flop/B  x{mult}"
            )
        ideal = 6 * cfg.param_count() * tokens
        print(
            f"  pipeline total {total_flops / 1e9:.2f} GFLOP vs 6·N·T ideal "
            f"{ideal / 1e9:.2f} GFLOP → ratio {total_flops / ideal:.2f}x "
            f"(>1 = recompute/attention overhead, <1 = sparse embed grads)"
        )


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--configs", default="tiny,e2e")
    args = ap.parse_args()
    report(args.configs.split(","))


if __name__ == "__main__":
    main()
