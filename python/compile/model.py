"""Layer 2 — LLaMa-family stage compute graphs in JAX.

The paper (Appendix A.1) trains LLaMa models split into pipeline stages:
stage ``S0`` holds the embedding **and** deembedding (+ final norm) — the
pipeline loops ``S0, S1, …, SL, S0`` — and each body stage holds an equal,
consecutive slice of transformer blocks.

This module defines exactly the per-stage functions the Rust coordinator
executes, in the flattened positional form the AOT pipeline lowers:

* ``embed_fwd(E, ids) -> h``                  — token embedding lookup.
* ``embed_bwd(E, ids, gh) -> gE``             — scatter-add VJP.
* ``body_fwd(p_0, …, p_{9n-1}, h) -> h'``     — ``n`` transformer blocks.
* ``body_bwd(p…, h, gh') -> (gh, gp…)``       — VJP wrt input and params.
* ``head_fwd(D, nw, h, ids) -> (loss,)``      — final norm, logits, mean
  next-token cross-entropy (targets = ids shifted left; last position
  masked).
* ``head_bwd(D, nw, h, ids) -> (loss, gh, gD, gnw)``.

Each transformer block is pre-norm LLaMa: RMSNorm → causal MHA with rotary
position embeddings → residual, RMSNorm → SwiGLU MLP → residual. RMSNorm
and attention are the Pallas kernels from :mod:`compile.kernels`.

Parameter flattening order (the contract with the Rust side, recorded in
the artifact manifest):

* body block: ``attn_norm, wq, wk, wv, wo, mlp_norm, w_gate, w_up, w_down``
* embed stage: ``embed (V,D), deembed (D,V), final_norm (D)``

Everything is float32: the CPU PJRT backend has no native bf16 advantage
and f32 keeps the Rust-side optimizer/recovery math exact.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp

from .kernels.adam import adam_update, grad_accumulate
from .kernels.attention import flash_attention
from .kernels.rmsnorm import rmsnorm

BLOCK_PARAM_NAMES = (
    "attn_norm",
    "wq",
    "wk",
    "wv",
    "wo",
    "mlp_norm",
    "w_gate",
    "w_up",
    "w_down",
)
EMBED_PARAM_NAMES = ("embed", "deembed", "final_norm")
N_BLOCK_PARAMS = len(BLOCK_PARAM_NAMES)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """One LLaMa pipeline configuration (paper Table 4 analogue)."""

    name: str
    vocab: int
    dim: int
    heads: int
    layers: int  # total transformer blocks across body stages
    body_stages: int  # paper's "Stages" (S0 with E/E^-1 is extra)
    ffn: int
    context: int
    microbatch: int
    learning_rate: float

    def __post_init__(self) -> None:
        if self.layers % self.body_stages:
            raise ValueError(
                f"{self.name}: layers {self.layers} not divisible by "
                f"body_stages {self.body_stages}"
            )
        if self.dim % self.heads:
            raise ValueError(f"{self.name}: dim {self.dim} % heads {self.heads} != 0")

    @property
    def blocks_per_stage(self) -> int:
        return self.layers // self.body_stages

    @property
    def head_dim(self) -> int:
        return self.dim // self.heads

    def block_param_shapes(self) -> list[tuple[str, tuple[int, ...]]]:
        d, f = self.dim, self.ffn
        return [
            ("attn_norm", (d,)),
            ("wq", (d, d)),
            ("wk", (d, d)),
            ("wv", (d, d)),
            ("wo", (d, d)),
            ("mlp_norm", (d,)),
            ("w_gate", (d, f)),
            ("w_up", (d, f)),
            ("w_down", (f, d)),
        ]

    def embed_param_shapes(self) -> list[tuple[str, tuple[int, ...]]]:
        return [
            ("embed", (self.vocab, self.dim)),
            ("deembed", (self.dim, self.vocab)),
            ("final_norm", (self.dim,)),
        ]

    def stage_param_shapes(self) -> list[tuple[str, tuple[int, ...]]]:
        """Flattened param shapes for ONE body stage (blocks_per_stage blocks)."""
        out = []
        for b in range(self.blocks_per_stage):
            for name, shape in self.block_param_shapes():
                out.append((f"block{b}.{name}", shape))
        return out

    def param_count(self) -> int:
        n = sum(
            int(jnp.prod(jnp.array(s))) for _, s in self.embed_param_shapes()
        )
        per_block = sum(
            int(jnp.prod(jnp.array(s))) for _, s in self.block_param_shapes()
        )
        return n + per_block * self.layers


def _ffn_llama(dim: int) -> int:
    """LLaMa SwiGLU hidden size: 4*dim*2/3 rounded to a multiple of 32."""
    f = int(4 * dim * 2 / 3)
    return (f + 31) // 32 * 32


# ---------------------------------------------------------------------------
# Presets. `tiny`/`e2e` are the CPU-scale workhorses (tests, examples,
# convergence experiments); `small124m`/`medium500m`/`large1p5b` are the
# paper's exact Table 4 rows (artifact generation supported, training at
# that scale is demonstrated for a handful of steps on this testbed —
# see DESIGN.md §2 Substitutions).
# ---------------------------------------------------------------------------
PRESETS: dict[str, ModelConfig] = {
    cfg.name: cfg
    for cfg in [
        ModelConfig("tiny", vocab=256, dim=64, heads=4, layers=4, body_stages=2,
                    ffn=_ffn_llama(64), context=32, microbatch=4,
                    learning_rate=1e-3),
        ModelConfig("e2e", vocab=512, dim=128, heads=4, layers=8, body_stages=4,
                    ffn=_ffn_llama(128), context=64, microbatch=8,
                    learning_rate=6e-4),
        ModelConfig("convergence", vocab=512, dim=192, heads=6, layers=12,
                    body_stages=4, ffn=_ffn_llama(192), context=64,
                    microbatch=8, learning_rate=6e-4),
        ModelConfig("small124m", vocab=32000, dim=512, heads=8, layers=12,
                    body_stages=4, ffn=_ffn_llama(512), context=512,
                    microbatch=4, learning_rate=6e-4),
        ModelConfig("medium500m", vocab=32000, dim=1024, heads=16, layers=24,
                    body_stages=6, ffn=_ffn_llama(1024), context=1024,
                    microbatch=2, learning_rate=3e-4),
        ModelConfig("large1p5b", vocab=32000, dim=2048, heads=16, layers=24,
                    body_stages=6, ffn=_ffn_llama(2048), context=4096,
                    microbatch=1, learning_rate=3e-4),
    ]
}


# ---------------------------------------------------------------------------
# Initialization (used by python tests; the Rust side reproduces the same
# scheme from the manifest's init spec — plain scaled-normal / ones).
# ---------------------------------------------------------------------------
def init_spec(name: str) -> dict:
    """Init rule per tensor name suffix: norms are ones, matrices N(0, 0.02)."""
    if name.endswith("norm"):
        return {"kind": "ones"}
    return {"kind": "normal", "std": 0.02}


def init_block_params(cfg: ModelConfig, key: jax.Array) -> list[jax.Array]:
    out = []
    for name, shape in cfg.block_param_shapes():
        spec = init_spec(name)
        if spec["kind"] == "ones":
            out.append(jnp.ones(shape, jnp.float32))
        else:
            key, sub = jax.random.split(key)
            out.append(jax.random.normal(sub, shape, jnp.float32) * spec["std"])
    return out


def init_stage_params(cfg: ModelConfig, key: jax.Array) -> list[jax.Array]:
    out = []
    for _ in range(cfg.blocks_per_stage):
        key, sub = jax.random.split(key)
        out.extend(init_block_params(cfg, sub))
    return out


def init_embed_params(cfg: ModelConfig, key: jax.Array) -> list[jax.Array]:
    k1, k2 = jax.random.split(key)
    return [
        jax.random.normal(k1, (cfg.vocab, cfg.dim), jnp.float32) * 0.02,
        jax.random.normal(k2, (cfg.dim, cfg.vocab), jnp.float32) * 0.02,
        jnp.ones((cfg.dim,), jnp.float32),
    ]


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------
def _rope_tables(seq: int, dh: int) -> tuple[jax.Array, jax.Array]:
    half = dh // 2
    freqs = 1.0 / (10000.0 ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    t = jnp.arange(seq, dtype=jnp.float32)
    ang = jnp.outer(t, freqs)  # (S, dh/2)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array) -> jax.Array:
    """Rotate pairs (x[..., :half], x[..., half:]). x: (BH, S, dh)."""
    _, s, dh = x.shape
    cos, sin = _rope_tables(s, dh)
    half = dh // 2
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1
    ).astype(x.dtype)


# ---------------------------------------------------------------------------
# Transformer block + stage functions
# ---------------------------------------------------------------------------
def _split_heads(x: jax.Array, heads: int) -> jax.Array:
    b, s, d = x.shape
    dh = d // heads
    return x.reshape(b, s, heads, dh).transpose(0, 2, 1, 3).reshape(b * heads, s, dh)


def _merge_heads(x: jax.Array, batch: int, heads: int) -> jax.Array:
    bh, s, dh = x.shape
    return (
        x.reshape(batch, heads, s, dh).transpose(0, 2, 1, 3).reshape(batch, s, heads * dh)
    )


def block_fwd(cfg: ModelConfig, p: Sequence[jax.Array], h: jax.Array) -> jax.Array:
    """One pre-norm LLaMa block. ``p`` in BLOCK_PARAM_NAMES order."""
    attn_norm, wq, wk, wv, wo, mlp_norm, w_gate, w_up, w_down = p
    b = h.shape[0]
    x = rmsnorm(h, attn_norm)
    q = _split_heads(x @ wq, cfg.heads)
    k = _split_heads(x @ wk, cfg.heads)
    v = _split_heads(x @ wv, cfg.heads)
    q = apply_rope(q)
    k = apply_rope(k)
    attn = _merge_heads(flash_attention(q, k, v), b, cfg.heads)
    h = h + attn @ wo
    x = rmsnorm(h, mlp_norm)
    mlp = (jax.nn.silu(x @ w_gate) * (x @ w_up)) @ w_down
    return h + mlp


def body_stage_fwd(cfg: ModelConfig, params: Sequence[jax.Array], h: jax.Array) -> jax.Array:
    """``blocks_per_stage`` blocks; ``params`` is the flat per-stage list."""
    n = N_BLOCK_PARAMS
    assert len(params) == n * cfg.blocks_per_stage, (
        f"expected {n * cfg.blocks_per_stage} params, got {len(params)}"
    )
    for i in range(cfg.blocks_per_stage):
        h = block_fwd(cfg, params[i * n : (i + 1) * n], h)
    return h


def embed_fwd(embed: jax.Array, ids: jax.Array) -> jax.Array:
    """``ids: (B, S) int32`` → ``(B, S, D)``."""
    return embed[ids]


def head_loss(
    deembed: jax.Array, final_norm: jax.Array, h: jax.Array, ids: jax.Array
) -> jax.Array:
    """Mean next-token cross-entropy (targets = ids shifted left)."""
    x = rmsnorm(h, final_norm)
    logits = x @ deembed  # (B, S, V)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    targets = jnp.roll(ids, -1, axis=1)
    tok_lp = jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    # mask the final position of each row (no next token)
    s = ids.shape[1]
    mask = (jnp.arange(s) < s - 1).astype(jnp.float32)[None, :]
    return -(tok_lp * mask).sum() / (mask.sum() * ids.shape[0])


# ---------------------------------------------------------------------------
# AOT entry points: flattened positional signatures
# ---------------------------------------------------------------------------
def make_entry_points(cfg: ModelConfig):
    """Build the eight flattened functions the AOT pipeline lowers.

    Returns ``{name: (fn, example_args)}``; shapes use ``cfg.microbatch`` ×
    ``cfg.context``. Besides the six stage-compute entries there are two
    optimizer entries operating on one body stage's flat parameter list
    (``P = 9 * blocks_per_stage`` tensors):

    * ``body_grad_accum(acc_0,…,acc_{P-1}, g_0,…,g_{P-1}) -> (sum…)`` —
      per-microbatch gradient accumulation on the owning stage's plane.
    * ``body_adam(p…, m…, v…, g…, scalars) -> (p'…, m'…, v'…, gm…)`` —
      the fused Adam step; ``scalars = [inv, lr, bc1, bc2]`` is the (4,)
      host-computed pack (see :func:`compile.kernels.ref.adam_scalars`).
    """
    b, s = cfg.microbatch, cfg.context
    f32, i32 = jnp.float32, jnp.int32
    spec = jax.ShapeDtypeStruct
    h_spec = spec((b, s, cfg.dim), f32)
    ids_spec = spec((b, s), i32)
    embed_spec = spec((cfg.vocab, cfg.dim), f32)
    deembed_spec = spec((cfg.dim, cfg.vocab), f32)
    norm_spec = spec((cfg.dim,), f32)
    scalars_spec = spec((4,), f32)
    stage_specs = [spec(shape, f32) for _, shape in cfg.stage_param_shapes()]

    def embed_fwd_fn(embed, ids):
        return (embed_fwd(embed, ids),)

    def embed_bwd_fn(embed, ids, gh):
        _, vjp = jax.vjp(lambda e: embed_fwd(e, ids), embed)
        return (vjp(gh)[0],)

    def body_fwd_fn(*args):
        params, h = args[:-1], args[-1]
        return (body_stage_fwd(cfg, params, h),)

    def body_bwd_fn(*args):
        params, h, g = args[:-2], args[-2], args[-1]
        _, vjp = jax.vjp(
            lambda *ph: body_stage_fwd(cfg, ph[:-1], ph[-1]), *params, h
        )
        grads = vjp(g)
        return (grads[-1],) + tuple(grads[:-1])  # (gh, gparams…)

    def head_fwd_fn(deembed, final_norm, h, ids):
        return (head_loss(deembed, final_norm, h, ids),)

    def head_bwd_fn(deembed, final_norm, h, ids):
        loss, grads = jax.value_and_grad(head_loss, argnums=(0, 1, 2))(
            deembed, final_norm, h, ids
        )
        gd, gn, gh = grads
        return (loss, gh, gd, gn)

    def body_grad_accum_fn(*args):
        n = len(args) // 2
        acc, g = args[:n], args[n:]
        return tuple(grad_accumulate(a, b) for a, b in zip(acc, g))

    def body_adam_fn(*args):
        n = (len(args) - 1) // 4
        p, m, v = args[:n], args[n : 2 * n], args[2 * n : 3 * n]
        g, scalars = args[3 * n : 4 * n], args[-1]
        outs = [
            adam_update(pi, mi, vi, gi, scalars)
            for pi, mi, vi, gi in zip(p, m, v, g)
        ]
        # group outputs like the inputs: all p', then m', v', gm — the Rust
        # side donates p/m/v/g positionally into these four groups.
        return tuple(o[j] for j in range(4) for o in outs)

    return {
        "embed_fwd": (embed_fwd_fn, (embed_spec, ids_spec)),
        "embed_bwd": (embed_bwd_fn, (embed_spec, ids_spec, h_spec)),
        "body_fwd": (body_fwd_fn, (*stage_specs, h_spec)),
        "body_bwd": (body_bwd_fn, (*stage_specs, h_spec, h_spec)),
        "head_fwd": (head_fwd_fn, (deembed_spec, norm_spec, h_spec, ids_spec)),
        "head_bwd": (head_bwd_fn, (deembed_spec, norm_spec, h_spec, ids_spec)),
        "body_grad_accum": (body_grad_accum_fn, (*stage_specs, *stage_specs)),
        "body_adam": (
            body_adam_fn,
            (*stage_specs, *stage_specs, *stage_specs, *stage_specs, scalars_spec),
        ),
    }
