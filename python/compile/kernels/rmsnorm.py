"""Fused RMSNorm as a Pallas kernel (Layer 1).

RMSNorm is memory-bound: one read of ``x``, one write of ``y``, a reduction
over the feature axis. On TPU this is a VPU (vector-unit) kernel: the grid
tiles the flattened row axis, each cell normalizes a ``(block_rows, D)``
tile held in VMEM in a single pass (reduction + scale fused — no separate
variance pass over HBM).

Same conventions as ``attention.py``: ``interpret=True`` so the lowered HLO
runs on the CPU PJRT client, and a ``jax.custom_vjp`` wrapper whose backward
is the jnp oracle's VJP.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import ref_rmsnorm

DEFAULT_BLOCK_ROWS = 128
EPS = 1e-5


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps) * w_ref[...].astype(jnp.float32)
    o_ref[...] = y.astype(o_ref.dtype)


def rmsnorm_pallas(
    x: jax.Array,
    w: jax.Array,
    *,
    eps: float = EPS,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = True,
) -> jax.Array:
    """Raw Pallas forward. ``x: (..., D)``, ``w: (D,)``."""
    orig_shape = x.shape
    d = orig_shape[-1]
    rows = 1
    for dim in orig_shape[:-1]:
        rows *= dim
    x2 = x.reshape(rows, d)
    block_rows = min(block_rows, rows)
    # Pad rows up to a multiple of the block (tail tile) — configs keep
    # rows = B*S a power of two so this is a no-op in practice.
    pad = (-rows) % block_rows
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    grid = (pl.cdiv(x2.shape[0], block_rows),)
    y = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x2.shape, x.dtype),
        interpret=interpret,
    )(x2, w)
    if pad:
        y = y[:rows]
    return y.reshape(orig_shape)


@jax.custom_vjp
def rmsnorm(x: jax.Array, w: jax.Array) -> jax.Array:
    """RMSNorm: Pallas forward, recompute-style jnp backward."""
    return rmsnorm_pallas(x, w)


def _rn_fwd(x, w):
    return rmsnorm_pallas(x, w), (x, w)


def _rn_bwd(res, g):
    x, w = res
    _, vjp = jax.vjp(ref_rmsnorm, x, w)
    return vjp(g)


rmsnorm.defvjp(_rn_fwd, _rn_bwd)
