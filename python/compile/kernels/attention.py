"""Fused causal multi-head attention as a Pallas kernel (Layer 1).

The paper trains LLaMa-family models whose per-stage hot spot is the
attention + MLP of each transformer block. On the paper's H100s this would
be a CUDA flash-attention; here it is re-thought for the TPU model that
Pallas exposes (see DESIGN.md §Hardware-Adaptation):

* The grid iterates ``(batch*heads, q_blocks)``; each grid cell owns a
  ``(block_q, dh)`` query tile resident in VMEM.
* K/V for the (batch, head) are streamed through the cell in ``block_k``
  chunks inside a ``fori_loop`` — the HBM→VMEM schedule a CUDA kernel would
  express with threadblock tiling is expressed with a block loop + dynamic
  slices here.
* The online-softmax recurrence (running max ``m``, normalizer ``l``,
  f32 accumulator) keeps memory linear in ``block_q`` — no ``S×S``
  materialization.
* Causal structure is exploited: a query tile only visits KV tiles up to
  its own diagonal (``kb_hi``), halving work.

``interpret=True`` is mandatory: the CPU PJRT plugin used by the Rust
runtime cannot execute Mosaic custom-calls, and interpret-mode lowers the
kernel into plain HLO that runs (and fuses) anywhere. Real-TPU efficiency is
estimated analytically in EXPERIMENTS.md §Perf.

The public entry point :func:`flash_attention` is a ``jax.custom_vjp``:
forward runs the Pallas kernel, backward recomputes attention with the
pure-jnp oracle and takes its VJP (flash-style recompute — no quadratic
residuals are saved between fwd and bwd).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import ref_attention

NEG_INF = -1e30

# Default tile sizes. 128 on the contracted/lane dim and multiples of 8 on
# sublanes map cleanly onto the MXU; for short sequences the tiles clamp to
# the sequence length.
DEFAULT_BLOCK_Q = 64
DEFAULT_BLOCK_K = 64


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, block_k: int, scale: float):
    """One grid cell: a (block_q, dh) query tile against streamed KV tiles."""
    qi = pl.program_id(1)
    block_q, dh = q_ref.shape
    seq_len = k_ref.shape[0]
    q = q_ref[...].astype(jnp.float32) * scale
    q_offset = qi * block_q

    def body(kb, carry):
        acc, m_prev, l_prev = carry
        k = k_ref[pl.ds(kb * block_k, block_k), :].astype(jnp.float32)
        v = v_ref[pl.ds(kb * block_k, block_k), :].astype(jnp.float32)
        s = q @ k.T  # (block_q, block_k) on the MXU
        # Causal mask for this (q tile, kv tile) pair.
        row = q_offset + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        col = kb * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(row >= col, s, NEG_INF)
        # Online softmax update.
        m_cur = jnp.max(s, axis=1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=1)
        acc = acc * alpha[:, None] + p @ v
        return acc, m_new, l_new

    acc = jnp.zeros((block_q, dh), jnp.float32)
    m0 = jnp.full((block_q,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    # Causal early exit: KV tiles strictly above the diagonal are skipped.
    kb_hi = jax.lax.div(q_offset + block_q - 1, block_k) + 1
    del seq_len  # bound is the causal limit, not the full sequence
    acc, _, l = jax.lax.fori_loop(0, kb_hi, body, (acc, m0, l0))
    o_ref[...] = (acc / l[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = True,
) -> jax.Array:
    """Raw Pallas forward. ``q, k, v: (BH, S, dh)`` → ``(BH, S, dh)``.

    ``S`` must be divisible by the (clamped) block sizes; model configs
    enforce this (contexts are powers of two ≥ 8).
    """
    bh, s, dh = q.shape
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    if s % block_q or s % block_k:
        raise ValueError(f"seq len {s} not divisible by blocks ({block_q},{block_k})")
    scale = 1.0 / (dh**0.5)
    grid = (bh, pl.cdiv(s, block_q))
    return pl.pallas_call(
        functools.partial(_attn_kernel, block_k=block_k, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, block_q, dh), lambda b, i: (b, i, 0)),
            pl.BlockSpec((None, s, dh), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((None, s, dh), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, dh), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, dh), q.dtype),
        interpret=interpret,
    )(q, k, v)


@jax.custom_vjp
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array) -> jax.Array:
    """Causal MHA: Pallas forward, recompute-style jnp backward."""
    return flash_attention_pallas(q, k, v)


def _fa_fwd(q, k, v):
    return flash_attention_pallas(q, k, v), (q, k, v)


def _fa_bwd(res, g):
    q, k, v = res
    _, vjp = jax.vjp(ref_attention, q, k, v)
    return vjp(g)


flash_attention.defvjp(_fa_fwd, _fa_bwd)


def vmem_bytes_estimate(s: int, dh: int, block_q: int = DEFAULT_BLOCK_Q,
                        block_k: int = DEFAULT_BLOCK_K, dtype_bytes: int = 4) -> int:
    """Analytic VMEM footprint of one grid cell (see DESIGN.md §7).

    q tile + full-KV residency + f32 accumulator + one (block_q, block_k)
    score tile. Used by the perf report, not by the kernel itself.
    """
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    q_tile = block_q * dh * dtype_bytes
    kv = 2 * s * dh * dtype_bytes
    acc = block_q * dh * 4
    score = block_q * block_k * 4
    return q_tile + kv + acc + score
