"""Pure-jnp reference oracles for the Pallas kernels.

These are the correctness ground truth: every Pallas kernel in this package
must match its `ref_*` counterpart to float32 tolerance (pytest + hypothesis
sweeps in ``python/tests/``). They are also used as the backward pass of the
``jax.custom_vjp`` wrappers around the Pallas forwards (flash-style
recompute: nothing quadratic is saved between fwd and bwd).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

# Adam hyperparameters (paper Appendix A.2), pre-rounded to f32 so the
# `1 - beta` style constants match the Rust host optimizer bit-for-bit:
# f32(1.0) - f32(0.9) = 0x3DCCCCD0, which is NOT f32(0.1) = 0x3DCCCCCD.
ADAM_BETA1 = np.float32(0.9)
ADAM_BETA2 = np.float32(0.999)
ADAM_EPS = np.float32(1e-8)
ADAM_ONE_MINUS_BETA1 = np.float32(1.0) - ADAM_BETA1
ADAM_ONE_MINUS_BETA2 = np.float32(1.0) - ADAM_BETA2


def ref_attention(q: jax.Array, k: jax.Array, v: jax.Array) -> jax.Array:
    """Causal scaled-dot-product attention.

    Args:
      q, k, v: ``(BH, S, dh)`` — batch*heads flattened leading dim.

    Returns:
      ``(BH, S, dh)`` attention output, same dtype as ``q``.
    """
    _, s, dh = q.shape
    scale = 1.0 / (dh**0.5)
    logits = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32), k.astype(jnp.float32))
    logits = logits * scale
    mask = jnp.tril(jnp.ones((s, s), bool))
    logits = jnp.where(mask, logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def ref_rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    """RMSNorm over the last axis: ``x * rsqrt(mean(x^2) + eps) * w``.

    Args:
      x: ``(..., D)``.
      w: ``(D,)`` scale.
    """
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32)
    return y.astype(x.dtype)


def adam_scalars(t: int, lr: float, microbatches: int) -> jax.Array:
    """The per-step scalar pack ``[inv, lr, bc1, bc2]`` the host uploads.

    ``inv`` is the mean-gradient scale ``1/microbatches``; ``bc1``/``bc2``
    are the step-``t`` bias corrections. All four are host-computed (the
    Rust side uses ``powi``) so the kernel sees them as data, keeping the
    on-device math free of any transcendental that could diverge from the
    host reference.
    """
    assert t >= 1, "bias correction is defined for steps t >= 1"
    bc1 = np.float32(1.0) - ADAM_BETA1**t
    bc2 = np.float32(1.0) - ADAM_BETA2**t
    return jnp.asarray(
        [np.float32(1.0) / np.float32(microbatches), np.float32(lr), bc1, bc2],
        jnp.float32,
    )


def ref_adam_step(
    p: jax.Array, m: jax.Array, v: jax.Array, g: jax.Array, scalars: jax.Array
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Fused-Adam oracle: one update on one tensor.

    Mirrors the Rust host optimizer (``rust/src/model/adam.rs``) operation
    for operation, including evaluation order — ``v' = b2*v + ((1-b2)*gm)*gm``
    and ``p' = p - (lr*(m'/bc1)) / (sqrt(v'/bc2) + eps)`` — so the Pallas
    kernel that matches this oracle also matches the host path.

    Returns ``(p', m', v', gm)`` where ``gm = g * inv`` is the mean gradient
    (kept as an output so the caller can lazily derive ``omega = ||gm||^2``).
    """
    inv, lr, bc1, bc2 = scalars[0], scalars[1], scalars[2], scalars[3]
    gm = g * inv
    m2 = ADAM_BETA1 * m + ADAM_ONE_MINUS_BETA1 * gm
    v2 = ADAM_BETA2 * v + (ADAM_ONE_MINUS_BETA2 * gm) * gm
    p2 = p - lr * (m2 / bc1) / (jnp.sqrt(v2 / bc2) + ADAM_EPS)
    return p2, m2, v2, gm


def ref_grad_accumulate(acc: jax.Array, g: jax.Array) -> jax.Array:
    """Gradient accumulation oracle: one elementwise add, same shape."""
    return acc + g
