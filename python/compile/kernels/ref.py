"""Pure-jnp reference oracles for the Pallas kernels.

These are the correctness ground truth: every Pallas kernel in this package
must match its `ref_*` counterpart to float32 tolerance (pytest + hypothesis
sweeps in ``python/tests/``). They are also used as the backward pass of the
``jax.custom_vjp`` wrappers around the Pallas forwards (flash-style
recompute: nothing quadratic is saved between fwd and bwd).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ref_attention(q: jax.Array, k: jax.Array, v: jax.Array) -> jax.Array:
    """Causal scaled-dot-product attention.

    Args:
      q, k, v: ``(BH, S, dh)`` — batch*heads flattened leading dim.

    Returns:
      ``(BH, S, dh)`` attention output, same dtype as ``q``.
    """
    _, s, dh = q.shape
    scale = 1.0 / (dh**0.5)
    logits = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32), k.astype(jnp.float32))
    logits = logits * scale
    mask = jnp.tril(jnp.ones((s, s), bool))
    logits = jnp.where(mask, logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def ref_rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    """RMSNorm over the last axis: ``x * rsqrt(mean(x^2) + eps) * w``.

    Args:
      x: ``(..., D)``.
      w: ``(D,)`` scale.
    """
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32)
    return y.astype(x.dtype)
