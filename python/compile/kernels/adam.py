"""Fused Adam update + gradient accumulation as Pallas kernels (Layer 1).

Both kernels are memory-bound elementwise VPU work, so the grid simply
tiles the *flattened* element axis — parameter tensors arrive in whatever
shape the manifest records ((D,), (D,D), (F,D), …) and are viewed as flat
rows for the kernel, exactly like the optimizer's flat host buffers on the
Rust side.

``adam_update`` fuses the whole optimizer step for one tensor into a
single pass: mean-scale the accumulated gradient, update both moments,
bias-correct, and write the new parameter — four reads, four writes, no
intermediate HBM traffic. Bias corrections (and the mean scale ``1/m``)
are **host-computed** and passed in as a tiny ``(4,)`` scalar pack: the
host uses ``powi``, and reproducing that on-device (``jnp.power``) would
not be bitwise-faithful. The kernel itself is pure f32 add/mul/div/sqrt
in exactly the host optimizer's evaluation order (see ``ref.py``).

``grad_accumulate`` is the device-resident replacement for
``GradBuffer::accumulate``: one elementwise add per microbatch, run on
the owning stage's plane so per-microbatch gradients never cross the
host boundary.

Same conventions as ``attention.py``/``rmsnorm.py``: ``interpret=True``
so the lowered HLO runs on the CPU PJRT client. No ``jax.custom_vjp``
wrapper — nothing differentiates through an optimizer step.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import (
    ADAM_BETA1,
    ADAM_BETA2,
    ADAM_EPS,
    ADAM_ONE_MINUS_BETA1,
    ADAM_ONE_MINUS_BETA2,
)

DEFAULT_BLOCK = 4096


def _adam_kernel(p_ref, m_ref, v_ref, g_ref, sc_ref, po_ref, mo_ref, vo_ref, gm_ref):
    # sc = [inv, lr, bc1, bc2] — see `ref.adam_scalars`.
    inv = sc_ref[0]
    lr = sc_ref[1]
    bc1 = sc_ref[2]
    bc2 = sc_ref[3]
    gm = g_ref[...] * inv
    m = ADAM_BETA1 * m_ref[...] + ADAM_ONE_MINUS_BETA1 * gm
    v = ADAM_BETA2 * v_ref[...] + (ADAM_ONE_MINUS_BETA2 * gm) * gm
    po_ref[...] = p_ref[...] - lr * (m / bc1) / (jnp.sqrt(v / bc2) + ADAM_EPS)
    mo_ref[...] = m
    vo_ref[...] = v
    gm_ref[...] = gm


def _accum_kernel(a_ref, g_ref, o_ref):
    o_ref[...] = a_ref[...] + g_ref[...]


def _flat_padded(x: jax.Array, block: int) -> tuple[jax.Array, int, int]:
    """Flatten to 1-D and zero-pad up to a block multiple (tail tile)."""
    n = x.size
    flat = x.reshape(n)
    pad = (-n) % block
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat, n, pad


def adam_update_pallas(
    p: jax.Array,
    m: jax.Array,
    v: jax.Array,
    g: jax.Array,
    scalars: jax.Array,
    *,
    block: int = DEFAULT_BLOCK,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Fused Adam step on one tensor → ``(p', m', v', gm)``.

    ``p``/``m``/``v``/``g`` share one shape; ``scalars`` is the ``(4,)``
    pack ``[inv, lr, bc1, bc2]``. The zero-padded tail is harmless: all
    four padded inputs are 0, so the padded outputs are finite garbage
    that is sliced away before reshaping back.
    """
    shape = p.shape
    block = min(block, max(p.size, 1))
    pf, n, _ = _flat_padded(p, block)
    mf, _, _ = _flat_padded(m, block)
    vf, _, _ = _flat_padded(v, block)
    gf, _, _ = _flat_padded(g, block)
    bspec = pl.BlockSpec((block,), lambda i: (i,))
    grid = (pl.cdiv(pf.shape[0], block),)
    outs = pl.pallas_call(
        _adam_kernel,
        grid=grid,
        in_specs=[bspec, bspec, bspec, bspec, pl.BlockSpec((4,), lambda i: (0,))],
        out_specs=(bspec, bspec, bspec, bspec),
        out_shape=tuple(
            jax.ShapeDtypeStruct(pf.shape, jnp.float32) for _ in range(4)
        ),
        interpret=interpret,
    )(pf, mf, vf, gf, scalars)
    return tuple(o[:n].reshape(shape) for o in outs)


def grad_accumulate_pallas(
    acc: jax.Array,
    g: jax.Array,
    *,
    block: int = DEFAULT_BLOCK,
    interpret: bool = True,
) -> jax.Array:
    """Elementwise ``acc + g`` for one tensor, shape preserved."""
    shape = acc.shape
    block = min(block, max(acc.size, 1))
    af, n, _ = _flat_padded(acc, block)
    gf, _, _ = _flat_padded(g, block)
    bspec = pl.BlockSpec((block,), lambda i: (i,))
    grid = (pl.cdiv(af.shape[0], block),)
    out = pl.pallas_call(
        _accum_kernel,
        grid=grid,
        in_specs=[bspec, bspec],
        out_specs=bspec,
        out_shape=jax.ShapeDtypeStruct(af.shape, jnp.float32),
        interpret=interpret,
    )(af, gf)
    return out[:n].reshape(shape)


# Aliases used by the AOT entry points (mirrors `flash_attention`/`rmsnorm`
# being the model-facing names).
adam_update = adam_update_pallas
grad_accumulate = grad_accumulate_pallas
