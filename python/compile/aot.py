"""AOT pipeline: lower the per-stage JAX functions to HLO text + manifest.

This is the only place Python runs in the whole system, and it runs once
(``make artifacts``). For each requested model config it lowers the six
stage entry points from :mod:`compile.model` and writes:

    artifacts/<config>/<entry>.hlo.txt     — HLO text module
    artifacts/<config>/manifest.json       — shapes, dtypes, param layout,
                                             init spec, artifact inventory

**Interchange format is HLO text, not a serialized ``HloModuleProto``**:
jax ≥ 0.5 emits protos with 64-bit instruction ids which the ``xla`` crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

The manifest is the contract with the Rust runtime: literal order on every
``execute`` call follows the manifest's ``inputs`` list, and stage parameter
buffers are flattened in ``param_layout`` order.

Usage::

    python -m compile.aot --out-dir ../artifacts --configs tiny,e2e
    python -m compile.aot --out-dir ../artifacts --configs all
"""

from __future__ import annotations

import argparse
import json
import math
import pathlib
import time

import jax
from jax._src.lib import xla_client as xc

from .kernels.attention import vmem_bytes_estimate
from .model import PRESETS, ModelConfig, init_spec, make_entry_points

DEFAULT_CONFIGS = ("tiny", "e2e")


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR → XlaComputation → HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec_json(s) -> dict:
    dtype = {"float32": "f32", "int32": "i32"}[str(s.dtype)]
    return {"shape": list(s.shape), "dtype": dtype}


def _layout_json(shapes: list[tuple[str, tuple[int, ...]]]) -> list[dict]:
    out = []
    offset = 0
    for name, shape in shapes:
        count = math.prod(shape)
        out.append(
            {
                "name": name,
                "shape": list(shape),
                "elements": count,
                "offset": offset,
                "init": init_spec(name),
            }
        )
        offset += count
    return out


def lower_config(cfg: ModelConfig, out_dir: pathlib.Path, verbose: bool = True) -> dict:
    """Lower all entry points for one config; return its manifest dict."""
    cfg_dir = out_dir / cfg.name
    cfg_dir.mkdir(parents=True, exist_ok=True)
    entries = make_entry_points(cfg)
    artifacts = {}
    for name, (fn, specs) in entries.items():
        t0 = time.time()
        lowered = jax.jit(fn, keep_unused=True).lower(*specs)
        text = to_hlo_text(lowered)
        path = cfg_dir / f"{name}.hlo.txt"
        path.write_text(text)
        out_avals = lowered.out_info
        outputs = [
            _spec_json(jax.ShapeDtypeStruct(o.shape, o.dtype))
            for o in jax.tree_util.tree_leaves(out_avals)
        ]
        artifacts[name] = {
            "file": f"{name}.hlo.txt",
            "inputs": [_spec_json(s) for s in specs],
            "outputs": outputs,
        }
        if verbose:
            print(
                f"  [{cfg.name}] {name}: {len(text)} chars, "
                f"{len(specs)} inputs, {len(outputs)} outputs "
                f"({time.time() - t0:.1f}s)"
            )

    manifest = {
        "format_version": 1,
        "config": {
            "name": cfg.name,
            "vocab": cfg.vocab,
            "dim": cfg.dim,
            "heads": cfg.heads,
            "layers": cfg.layers,
            "body_stages": cfg.body_stages,
            "blocks_per_stage": cfg.blocks_per_stage,
            "ffn": cfg.ffn,
            "context": cfg.context,
            "microbatch": cfg.microbatch,
            "learning_rate": cfg.learning_rate,
            "param_count": cfg.param_count(),
        },
        "param_layout": {
            "embed_stage": _layout_json(cfg.embed_param_shapes()),
            "body_stage": _layout_json(cfg.stage_param_shapes()),
        },
        "perf": {
            "attn_vmem_bytes_per_cell": vmem_bytes_estimate(
                cfg.context, cfg.head_dim
            ),
        },
        "artifacts": artifacts,
    }
    (cfg_dir / "manifest.json").write_text(json.dumps(manifest, indent=1))
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--configs",
        default=",".join(DEFAULT_CONFIGS),
        help=f"comma-separated preset names or 'all' (presets: {sorted(PRESETS)})",
    )
    args = ap.parse_args()
    names = sorted(PRESETS) if args.configs == "all" else args.configs.split(",")
    out_dir = pathlib.Path(args.out_dir)
    for name in names:
        cfg = PRESETS[name]
        print(f"lowering config '{name}' ({cfg.param_count() / 1e6:.1f}M params)")
        lower_config(cfg, out_dir)
    print(f"artifacts written to {out_dir.resolve()}")


if __name__ == "__main__":
    main()
