"""Perf-analysis tooling: HLO cost model sanity and structural targets.

These tests pin the §Perf invariants: every shipped config fits the
16 MiB VMEM budget, the HLO cost analysis is self-consistent (backward ≈
2× forward FLOPs, costs scale with model size), and MXU alignment math is
correct.
"""

import pytest

from compile.kernels.attention import vmem_bytes_estimate
from compile.model import PRESETS
from compile.perf import hlo_cost, mxu_alignment


@pytest.fixture(scope="module")
def tiny_costs():
    from compile.model import make_entry_points

    cfg = PRESETS["tiny"]
    return {name: hlo_cost(fn, specs) for name, (fn, specs) in make_entry_points(cfg).items()}


class TestHloCost:
    def test_backward_costs_more_than_forward(self, tiny_costs):
        assert tiny_costs["body_bwd"]["flops"] > 2.0 * tiny_costs["body_fwd"]["flops"]
        assert tiny_costs["head_bwd"]["flops"] > tiny_costs["head_fwd"]["flops"]

    def test_body_dominates_embed(self, tiny_costs):
        assert tiny_costs["body_fwd"]["flops"] > 100 * tiny_costs["embed_fwd"]["flops"]

    def test_bytes_accessed_positive(self, tiny_costs):
        for name, c in tiny_costs.items():
            assert c["bytes accessed"] > 0, name

    def test_body_fwd_flops_match_analytic(self, tiny_costs):
        """body_fwd ≈ 2 · stage_params · tokens (dense matmul estimate)."""
        cfg = PRESETS["tiny"]
        per_block = sum(
            int(__import__("math").prod(s)) for _, s in cfg.block_param_shapes()
        )
        stage_params = per_block * cfg.blocks_per_stage
        tokens = cfg.microbatch * cfg.context
        analytic = 2 * stage_params * tokens
        got = tiny_costs["body_fwd"]["flops"]
        # attention quadratic term and norms push it above the matmul floor
        assert 0.8 * analytic < got < 3.0 * analytic, (got, analytic)


class TestStructuralTargets:
    @pytest.mark.parametrize("name", sorted(PRESETS))
    def test_all_configs_fit_vmem(self, name):
        cfg = PRESETS[name]
        assert vmem_bytes_estimate(cfg.context, cfg.head_dim) < 16 * 2**20, name

    def test_mxu_alignment_bounds(self):
        assert mxu_alignment(128) == 1.0
        assert mxu_alignment(256) == 1.0
        assert mxu_alignment(192) == pytest.approx(128 / 192)
        assert mxu_alignment(64) == pytest.approx(0.5)

    def test_paper_scale_dims_fully_aligned(self):
        for name in ["small124m", "medium500m", "large1p5b"]:
            cfg = PRESETS[name]
            assert mxu_alignment(cfg.dim) == 1.0, name
