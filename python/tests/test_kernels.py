"""L1 correctness: Pallas kernels vs the pure-jnp oracles in ref.py.

This is the CORE correctness signal for Layer 1. hypothesis sweeps shapes
(batch*heads, sequence, head-dim, row counts) and checks allclose; explicit
tests cover gradients through the custom_vjp wrappers and the tiling edge
cases (single block, many blocks, non-square tiles).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.adam import adam_update_pallas, grad_accumulate_pallas
from compile.kernels.attention import (
    flash_attention,
    flash_attention_pallas,
    vmem_bytes_estimate,
)
from compile.kernels.ref import (
    adam_scalars,
    ref_adam_step,
    ref_attention,
    ref_grad_accumulate,
    ref_rmsnorm,
)
from compile.kernels.rmsnorm import rmsnorm, rmsnorm_pallas

ATOL = 2e-5
RTOL = 2e-5


def _qkv(key, bh, s, dh, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    return tuple(jax.random.normal(k, (bh, s, dh), dtype) for k in ks)


# ---------------------------------------------------------------------------
# attention forward
# ---------------------------------------------------------------------------
class TestAttentionForward:
    @pytest.mark.parametrize("bh,s,dh", [
        (1, 8, 8),        # single tile
        (2, 64, 16),      # exactly one q block
        (4, 128, 32),     # multiple q and k blocks
        (3, 256, 16),     # more k blocks than q rows per block
        (8, 16, 64),      # wide head dim
    ])
    def test_matches_ref(self, bh, s, dh):
        q, k, v = _qkv(jax.random.PRNGKey(0), bh, s, dh)
        out = flash_attention_pallas(q, k, v)
        np.testing.assert_allclose(out, ref_attention(q, k, v), atol=ATOL, rtol=RTOL)

    def test_block_sizes_dont_change_result(self):
        q, k, v = _qkv(jax.random.PRNGKey(1), 2, 128, 16)
        base = flash_attention_pallas(q, k, v, block_q=128, block_k=128)
        for bq, bk in [(16, 16), (32, 64), (64, 32), (128, 16)]:
            out = flash_attention_pallas(q, k, v, block_q=bq, block_k=bk)
            np.testing.assert_allclose(out, base, atol=ATOL, rtol=RTOL)

    def test_causality(self):
        """Changing future tokens must not change earlier outputs."""
        q, k, v = _qkv(jax.random.PRNGKey(2), 1, 64, 16)
        out1 = flash_attention_pallas(q, k, v)
        k2 = k.at[:, 32:, :].set(99.0)
        v2 = v.at[:, 32:, :].set(-99.0)
        out2 = flash_attention_pallas(q, k2, v2)
        np.testing.assert_allclose(out1[:, :32], out2[:, :32], atol=ATOL, rtol=RTOL)

    def test_indivisible_seq_raises(self):
        q, k, v = _qkv(jax.random.PRNGKey(3), 1, 48, 8)
        with pytest.raises(ValueError, match="not divisible"):
            flash_attention_pallas(q, k, v, block_q=64, block_k=32)

    def test_jit_compatible(self):
        q, k, v = _qkv(jax.random.PRNGKey(4), 2, 32, 8)
        out = jax.jit(flash_attention)(q, k, v)
        np.testing.assert_allclose(out, ref_attention(q, k, v), atol=ATOL, rtol=RTOL)

    @settings(max_examples=25, deadline=None)
    @given(
        bh=st.integers(1, 4),
        s_pow=st.integers(3, 8),  # 8..256
        dh_pow=st.integers(2, 5),  # 4..32
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_shapes(self, bh, s_pow, dh_pow, seed):
        s, dh = 2**s_pow, 2**dh_pow
        q, k, v = _qkv(jax.random.PRNGKey(seed), bh, s, dh)
        out = flash_attention_pallas(q, k, v)
        np.testing.assert_allclose(out, ref_attention(q, k, v), atol=5e-5, rtol=5e-5)

    def test_extreme_values_stable(self):
        """Online softmax must not overflow with large logits."""
        q, k, v = _qkv(jax.random.PRNGKey(5), 1, 64, 8)
        out = flash_attention_pallas(q * 100.0, k * 100.0, v)
        assert bool(jnp.all(jnp.isfinite(out)))


# ---------------------------------------------------------------------------
# attention backward (custom_vjp)
# ---------------------------------------------------------------------------
class TestAttentionBackward:
    def test_grads_match_ref(self):
        q, k, v = _qkv(jax.random.PRNGKey(6), 2, 64, 16)

        def loss_pallas(q, k, v):
            return jnp.sum(flash_attention(q, k, v) ** 2)

        def loss_ref(q, k, v):
            return jnp.sum(ref_attention(q, k, v) ** 2)

        gp = jax.grad(loss_pallas, argnums=(0, 1, 2))(q, k, v)
        gr = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
        for a, b in zip(gp, gr):
            np.testing.assert_allclose(a, b, atol=1e-4, rtol=1e-4)

    def test_grad_finite_differences(self):
        q, k, v = _qkv(jax.random.PRNGKey(7), 1, 16, 4)
        w = jax.random.normal(jax.random.PRNGKey(8), q.shape)

        def f(q):
            return jnp.vdot(flash_attention(q, k, v), w)

        g = jax.grad(f)(q)
        eps = 1e-3
        d = jax.random.normal(jax.random.PRNGKey(9), q.shape)
        num = (f(q + eps * d) - f(q - eps * d)) / (2 * eps)
        np.testing.assert_allclose(jnp.vdot(g, d), num, rtol=2e-2)


# ---------------------------------------------------------------------------
# rmsnorm
# ---------------------------------------------------------------------------
class TestRmsNorm:
    @pytest.mark.parametrize("shape", [(4, 8), (2, 16, 32), (1, 128), (256, 64), (3, 5, 7, 16)])
    def test_matches_ref(self, shape):
        key = jax.random.PRNGKey(0)
        x = jax.random.normal(key, shape)
        w = jax.random.normal(jax.random.PRNGKey(1), (shape[-1],))
        np.testing.assert_allclose(
            rmsnorm_pallas(x, w), ref_rmsnorm(x, w), atol=ATOL, rtol=RTOL
        )

    def test_row_padding_path(self):
        """Row counts not divisible by the block exercise the pad/unpad path."""
        x = jax.random.normal(jax.random.PRNGKey(2), (130, 16))
        w = jnp.ones((16,))
        np.testing.assert_allclose(
            rmsnorm_pallas(x, w, block_rows=64), ref_rmsnorm(x, w), atol=ATOL, rtol=RTOL
        )

    def test_unit_scale_preserves_rms(self):
        x = jax.random.normal(jax.random.PRNGKey(3), (32, 64)) * 3.0
        y = rmsnorm_pallas(x, jnp.ones((64,)))
        rms = jnp.sqrt(jnp.mean(y * y, axis=-1))
        np.testing.assert_allclose(rms, jnp.ones_like(rms), atol=1e-3)

    def test_grads_match_ref(self):
        x = jax.random.normal(jax.random.PRNGKey(4), (8, 32))
        w = jax.random.normal(jax.random.PRNGKey(5), (32,))
        gp = jax.grad(lambda x, w: jnp.sum(rmsnorm(x, w) ** 2), argnums=(0, 1))(x, w)
        gr = jax.grad(lambda x, w: jnp.sum(ref_rmsnorm(x, w) ** 2), argnums=(0, 1))(x, w)
        for a, b in zip(gp, gr):
            np.testing.assert_allclose(a, b, atol=1e-4, rtol=1e-4)

    @settings(max_examples=25, deadline=None)
    @given(
        rows=st.integers(1, 300),
        d_pow=st.integers(2, 7),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_shapes(self, rows, d_pow, seed):
        d = 2**d_pow
        x = jax.random.normal(jax.random.PRNGKey(seed), (rows, d))
        w = jax.random.normal(jax.random.PRNGKey(seed + 1), (d,))
        np.testing.assert_allclose(
            rmsnorm_pallas(x, w), ref_rmsnorm(x, w), atol=5e-5, rtol=5e-5
        )


# ---------------------------------------------------------------------------
# fused Adam + grad accumulate (device-resident optimizer kernels)
# ---------------------------------------------------------------------------
def _pmvg(key, shape):
    kp, km, kv2, kg = jax.random.split(key, 4)
    p = jax.random.normal(kp, shape)
    m = jax.random.normal(km, shape) * 0.1
    # second moment must be non-negative (it is an EMA of squares)
    v = jax.random.normal(kv2, shape) ** 2
    g = jax.random.normal(kg, shape)
    return p, m, v, g


class TestFusedAdam:
    @pytest.mark.parametrize("shape", [(7,), (64,), (64, 64), (64, 176), (3, 5, 7)])
    def test_matches_ref(self, shape):
        p, m, v, g = _pmvg(jax.random.PRNGKey(0), shape)
        sc = adam_scalars(t=3, lr=1e-3, microbatches=4)
        got = adam_update_pallas(p, m, v, g, sc)
        want = ref_adam_step(p, m, v, g, sc)
        for a, b in zip(got, want):
            assert a.shape == shape
            np.testing.assert_allclose(a, b, atol=ATOL, rtol=RTOL)

    def test_bias_correction_t1_first_step_moves_by_lr(self):
        """At t=1 with zero moments, |Δp| ≈ lr regardless of gradient scale
        — the classic bias-correction identity the host optimizer also
        pins (`adam.rs::first_step_moves_by_lr`)."""
        shape = (32,)
        p = jnp.zeros(shape)
        m = jnp.zeros(shape)
        v = jnp.zeros(shape)
        g = jnp.full(shape, 123.0)
        sc = adam_scalars(t=1, lr=0.01, microbatches=1)
        p2, m2, v2, gm = adam_update_pallas(p, m, v, g, sc)
        np.testing.assert_allclose(p2, -0.01 * jnp.ones(shape), atol=1e-6)
        want = ref_adam_step(p, m, v, g, sc)
        for a, b in zip((p2, m2, v2, gm), want):
            np.testing.assert_allclose(a, b, atol=ATOL, rtol=RTOL)

    def test_bias_correction_large_t(self):
        """At large t the corrections are ~1; the kernel must still match
        the oracle exactly through the host-supplied scalar pack."""
        p, m, v, g = _pmvg(jax.random.PRNGKey(1), (128,))
        for t in (1000, 100_000):
            sc = adam_scalars(t=t, lr=3e-4, microbatches=8)
            got = adam_update_pallas(p, m, v, g, sc)
            want = ref_adam_step(p, m, v, g, sc)
            for a, b in zip(got, want):
                np.testing.assert_allclose(a, b, atol=ATOL, rtol=RTOL)

    def test_mean_scale_folded_in(self):
        """gm output must be g/microbatches, and feeding a pre-scaled
        gradient with inv=1 must give the same update."""
        p, m, v, g = _pmvg(jax.random.PRNGKey(2), (64,))
        sc4 = adam_scalars(t=5, lr=1e-3, microbatches=4)
        got4 = adam_update_pallas(p, m, v, g, sc4)
        np.testing.assert_allclose(got4[3], g / 4.0, atol=ATOL, rtol=RTOL)
        sc1 = adam_scalars(t=5, lr=1e-3, microbatches=1)
        got1 = adam_update_pallas(p, m, v, g / 4.0, sc1)
        for a, b in zip(got4, got1):
            np.testing.assert_allclose(a, b, atol=ATOL, rtol=RTOL)

    def test_block_tiling_and_padding_path(self):
        """Element counts not divisible by the block exercise pad/unpad."""
        p, m, v, g = _pmvg(jax.random.PRNGKey(3), (130, 16))
        sc = adam_scalars(t=2, lr=1e-3, microbatches=2)
        base = adam_update_pallas(p, m, v, g, sc)
        for block in (64, 100, 2048):
            got = adam_update_pallas(p, m, v, g, sc, block=block)
            for a, b in zip(got, base):
                np.testing.assert_allclose(a, b, atol=ATOL, rtol=RTOL)

    @settings(max_examples=25, deadline=None)
    @given(
        n=st.integers(1, 500),
        t=st.integers(1, 10_000),
        mb=st.integers(1, 16),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_shapes(self, n, t, mb, seed):
        p, m, v, g = _pmvg(jax.random.PRNGKey(seed), (n,))
        sc = adam_scalars(t=t, lr=1e-3, microbatches=mb)
        got = adam_update_pallas(p, m, v, g, sc, block=64)
        want = ref_adam_step(p, m, v, g, sc)
        for a, b in zip(got, want):
            np.testing.assert_allclose(a, b, atol=5e-5, rtol=5e-5)


class TestGradAccumulate:
    @pytest.mark.parametrize("shape", [(5,), (64,), (64, 176), (2, 3, 4)])
    def test_matches_ref(self, shape):
        acc = jax.random.normal(jax.random.PRNGKey(0), shape)
        g = jax.random.normal(jax.random.PRNGKey(1), shape)
        np.testing.assert_allclose(
            grad_accumulate_pallas(acc, g),
            ref_grad_accumulate(acc, g),
            atol=ATOL,
            rtol=RTOL,
        )

    def test_repeated_accumulation_matches_sum(self):
        """m microbatches accumulated one by one == left-to-right sum —
        the same order the Rust ordered sink enforces."""
        gs = [
            jax.random.normal(jax.random.PRNGKey(i), (40, 16)) for i in range(4)
        ]
        acc = gs[0]
        want = gs[0]
        for g in gs[1:]:
            acc = grad_accumulate_pallas(acc, g)
            want = ref_grad_accumulate(want, g)
        np.testing.assert_allclose(acc, want, atol=ATOL, rtol=RTOL)

    def test_padding_path(self):
        acc = jax.random.normal(jax.random.PRNGKey(2), (130,))
        g = jax.random.normal(jax.random.PRNGKey(3), (130,))
        np.testing.assert_allclose(
            grad_accumulate_pallas(acc, g, block=64),
            ref_grad_accumulate(acc, g),
            atol=ATOL,
            rtol=RTOL,
        )

    @settings(max_examples=25, deadline=None)
    @given(n=st.integers(1, 400), seed=st.integers(0, 2**31 - 1))
    def test_hypothesis_shapes(self, n, seed):
        acc = jax.random.normal(jax.random.PRNGKey(seed), (n,))
        g = jax.random.normal(jax.random.PRNGKey(seed + 1), (n,))
        np.testing.assert_allclose(
            grad_accumulate_pallas(acc, g, block=64),
            ref_grad_accumulate(acc, g),
            atol=5e-5,
            rtol=5e-5,
        )


# ---------------------------------------------------------------------------
# perf-model helpers
# ---------------------------------------------------------------------------
class TestVmemEstimate:
    def test_monotone_in_seq(self):
        assert vmem_bytes_estimate(512, 64) > vmem_bytes_estimate(64, 64)

    def test_small_config_fits_vmem(self):
        # 16 MiB VMEM per TPU core: all shipped configs must fit.
        assert vmem_bytes_estimate(4096, 128) < 16 * 2**20
