"""L2 correctness: stage graphs — shapes, gradients, end-to-end trainability.

Validates the exact functions the AOT pipeline lowers: forward chaining
(embed → body stages → head) reproduces a monolithic reference model built
purely from ref.py ops, backward entry points agree with autodiff of that
reference, and a few optimizer steps reduce the loss (the signal the Rust
coordinator consumes).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels.ref import ref_attention, ref_rmsnorm
from compile.model import (
    BLOCK_PARAM_NAMES,
    N_BLOCK_PARAMS,
    PRESETS,
    ModelConfig,
    apply_rope,
    block_fwd,
    body_stage_fwd,
    embed_fwd,
    head_loss,
    init_embed_params,
    init_stage_params,
    make_entry_points,
)

CFG = PRESETS["tiny"]


@pytest.fixture(scope="module")
def params():
    key = jax.random.PRNGKey(42)
    ks = jax.random.split(key, CFG.body_stages + 2)
    stages = [init_stage_params(CFG, k) for k in ks[: CFG.body_stages]]
    embed = init_embed_params(CFG, ks[-2])
    ids = jax.random.randint(ks[-1], (CFG.microbatch, CFG.context), 0, CFG.vocab)
    return stages, embed, ids


# ---------------------------------------------------------------------------
# Reference monolith built from ref.py ops only (no pallas)
# ---------------------------------------------------------------------------
def _ref_block(cfg: ModelConfig, p, h):
    attn_norm, wq, wk, wv, wo, mlp_norm, w_gate, w_up, w_down = p
    b, s, d = h.shape
    dh = d // cfg.heads

    def split(x):
        return x.reshape(b, s, cfg.heads, dh).transpose(0, 2, 1, 3).reshape(b * cfg.heads, s, dh)

    x = ref_rmsnorm(h, attn_norm)
    q, k, v = split(x @ wq), split(x @ wk), split(x @ wv)
    q, k = apply_rope(q), apply_rope(k)
    a = ref_attention(q, k, v)
    a = a.reshape(b, cfg.heads, s, dh).transpose(0, 2, 1, 3).reshape(b, s, d)
    h = h + a @ wo
    x = ref_rmsnorm(h, mlp_norm)
    return h + (jax.nn.silu(x @ w_gate) * (x @ w_up)) @ w_down


def _ref_forward_loss(cfg: ModelConfig, stages, embed_params, ids):
    E, D, nw = embed_params
    h = E[ids]
    for sp in stages:
        for i in range(cfg.blocks_per_stage):
            h = _ref_block(cfg, sp[i * N_BLOCK_PARAMS : (i + 1) * N_BLOCK_PARAMS], h)
    x = ref_rmsnorm(h, nw)
    logits = x @ D
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    targets = jnp.roll(ids, -1, axis=1)
    tok_lp = jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    s = ids.shape[1]
    mask = (jnp.arange(s) < s - 1).astype(jnp.float32)[None, :]
    return -(tok_lp * mask).sum() / (mask.sum() * ids.shape[0])


# ---------------------------------------------------------------------------
# forward parity
# ---------------------------------------------------------------------------
class TestForward:
    def test_block_matches_ref(self, params):
        stages, _, _ = params
        h = jax.random.normal(jax.random.PRNGKey(0), (2, CFG.context, CFG.dim))
        got = block_fwd(CFG, stages[0][:N_BLOCK_PARAMS], h)
        want = _ref_block(CFG, stages[0][:N_BLOCK_PARAMS], h)
        np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-4)

    def test_pipeline_matches_monolith(self, params):
        stages, embed_params, ids = params
        E, D, nw = embed_params
        h = embed_fwd(E, ids)
        for sp in stages:
            h = body_stage_fwd(CFG, sp, h)
        loss = head_loss(D, nw, h, ids)
        ref = _ref_forward_loss(CFG, stages, embed_params, ids)
        np.testing.assert_allclose(loss, ref, atol=1e-4, rtol=1e-4)

    def test_initial_loss_near_uniform(self, params):
        """Untrained model ≈ uniform over vocab: loss ≈ ln(V)."""
        stages, embed_params, ids = params
        E, D, nw = embed_params
        h = embed_fwd(E, ids)
        for sp in stages:
            h = body_stage_fwd(CFG, sp, h)
        loss = head_loss(D, nw, h, ids)
        assert abs(float(loss) - np.log(CFG.vocab)) < 0.5

    def test_wrong_param_count_asserts(self):
        h = jnp.zeros((1, CFG.context, CFG.dim))
        with pytest.raises(AssertionError):
            body_stage_fwd(CFG, [jnp.zeros((CFG.dim,))] * 3, h)


# ---------------------------------------------------------------------------
# backward entry points vs autodiff of the chained forward
# ---------------------------------------------------------------------------
class TestBackward:
    def test_chained_bwd_matches_monolith_grad(self, params):
        """Full manual backward chain == jax.grad of the monolith."""
        stages, embed_params, ids = params
        E, D, nw = embed_params
        eps = make_entry_points(CFG)

        # forward, saving stage inputs
        h0 = eps["embed_fwd"][0](E, ids)[0]
        hs = [h0]
        for sp in stages:
            hs.append(eps["body_fwd"][0](*sp, hs[-1])[0])

        # backward chain through the entry points
        loss, gh, gD, gnw = eps["head_bwd"][0](D, nw, hs[-1], ids)
        stage_grads = []
        for sp, hin in zip(reversed(stages), reversed(hs[:-1])):
            outs = eps["body_bwd"][0](*sp, hin, gh)
            gh, gp = outs[0], outs[1:]
            stage_grads.append(gp)
        stage_grads.reverse()
        gE = eps["embed_bwd"][0](E, ids, gh)[0]

        # autodiff ground truth
        def monolith(E, D, nw, stages_flat):
            return _ref_forward_loss(CFG, stages_flat, (E, D, nw), ids)

        ref_loss, ref_grads = jax.value_and_grad(monolith, argnums=(0, 1, 2, 3))(
            E, D, nw, [list(s) for s in stages]
        )
        rE, rD, rnw, rstages = ref_grads
        np.testing.assert_allclose(loss, ref_loss, atol=1e-4, rtol=1e-4)
        np.testing.assert_allclose(gE, rE, atol=1e-3, rtol=1e-3)
        np.testing.assert_allclose(gD, rD, atol=1e-3, rtol=1e-3)
        np.testing.assert_allclose(gnw, rnw, atol=1e-3, rtol=1e-3)
        for got_stage, ref_stage in zip(stage_grads, rstages):
            for g, r in zip(got_stage, ref_stage):
                np.testing.assert_allclose(g, r, atol=1e-3, rtol=1e-3)

    def test_body_bwd_output_order(self, params):
        """body_bwd returns (gh, then params in flattening order)."""
        stages, _, _ = params
        eps = make_entry_points(CFG)
        h = jax.random.normal(jax.random.PRNGKey(1), (CFG.microbatch, CFG.context, CFG.dim))
        g = jnp.ones_like(h)
        outs = eps["body_bwd"][0](*stages[0], h, g)
        assert outs[0].shape == h.shape
        shapes = [tuple(p.shape) for p in stages[0]]
        assert [tuple(o.shape) for o in outs[1:]] == shapes


# ---------------------------------------------------------------------------
# trainability: a few SGD steps through the entry points reduce loss
# ---------------------------------------------------------------------------
class TestTrainability:
    def test_loss_decreases(self, params):
        stages, embed_params, ids = params
        E, D, nw = embed_params
        stages = [list(s) for s in stages]
        eps = make_entry_points(CFG)
        lr = 0.05
        losses = []
        for _ in range(8):
            h0 = eps["embed_fwd"][0](E, ids)[0]
            hs = [h0]
            for sp in stages:
                hs.append(eps["body_fwd"][0](*sp, hs[-1])[0])
            loss, gh, gD, gnw = eps["head_bwd"][0](D, nw, hs[-1], ids)
            losses.append(float(loss))
            new_stages = []
            for sp, hin in zip(reversed(stages), reversed(hs[:-1])):
                outs = eps["body_bwd"][0](*sp, hin, gh)
                gh, gp = outs[0], outs[1:]
                new_stages.append([p - lr * g for p, g in zip(sp, gp)])
            new_stages.reverse()
            stages = new_stages
            gE = eps["embed_bwd"][0](E, ids, gh)[0]
            E, D, nw = E - lr * gE, D - lr * gD, nw - lr * gnw
        assert losses[-1] < losses[0] - 0.3, losses


# ---------------------------------------------------------------------------
# config validation
# ---------------------------------------------------------------------------
class TestConfigs:
    def test_presets_paper_table4(self):
        """Paper Table 4 hyperparameters are encoded faithfully."""
        s = PRESETS["small124m"]
        assert (s.dim, s.heads, s.layers, s.body_stages, s.context) == (512, 8, 12, 4, 512)
        m = PRESETS["medium500m"]
        assert (m.dim, m.heads, m.layers, m.body_stages, m.context) == (1024, 16, 24, 6, 1024)
        l = PRESETS["large1p5b"]
        assert (l.dim, l.heads, l.layers, l.body_stages, l.context) == (2048, 16, 24, 6, 4096)
        assert s.learning_rate == 6e-4 and m.learning_rate == 3e-4 and l.learning_rate == 3e-4

    def test_param_counts_match_paper_scale(self):
        # paper: 124M / 500M / 1.5B. With the paper's Table 4 dims and a
        # 32k vocab, the strict LLaMa block (SwiGLU ffn = 8/3·dim) gives
        # ~71M for "small" — the paper's 124M label presumably counts a
        # GPT-2-style 50k vocab; dims are what we hold faithful.
        assert 60e6 < PRESETS["small124m"].param_count() < 160e6
        assert 350e6 < PRESETS["medium500m"].param_count() < 650e6
        assert 1.1e9 < PRESETS["large1p5b"].param_count() < 2.0e9

    def test_invalid_configs_raise(self):
        with pytest.raises(ValueError):
            ModelConfig("bad", 256, 64, 4, 5, 2, 128, 32, 4, 1e-3)  # 5 % 2
        with pytest.raises(ValueError):
            ModelConfig("bad2", 256, 65, 4, 4, 2, 128, 32, 4, 1e-3)  # 65 % 4

    def test_block_param_names_stable(self):
        assert BLOCK_PARAM_NAMES == (
            "attn_norm", "wq", "wk", "wv", "wo", "mlp_norm", "w_gate", "w_up", "w_down",
        )
