"""AOT pipeline: manifests are consistent, HLO text round-trips and executes.

These tests compile each lowered HLO-text artifact back through the local
XLA client and check the numbers against the eager entry points — the same
load path the Rust runtime uses (text → parse → compile → execute).
"""

import json
import math
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax._src.lib import xla_client as xc

from compile.aot import lower_config, to_hlo_text
from compile.kernels.ref import adam_scalars
from compile.model import PRESETS, init_embed_params, init_stage_params, make_entry_points

CFG = PRESETS["tiny"]


@pytest.fixture(scope="module")
def artifacts(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    manifest = lower_config(CFG, out, verbose=False)
    return out / CFG.name, manifest


class TestManifest:
    def test_artifact_inventory(self, artifacts):
        cfg_dir, manifest = artifacts
        expected = {
            "embed_fwd", "embed_bwd", "body_fwd", "body_bwd", "head_fwd",
            "head_bwd", "body_grad_accum", "body_adam",
        }
        assert set(manifest["artifacts"]) == expected
        for art in manifest["artifacts"].values():
            assert (cfg_dir / art["file"]).stat().st_size > 0

    def test_config_roundtrip(self, artifacts):
        _, manifest = artifacts
        c = manifest["config"]
        assert c["name"] == CFG.name
        assert c["param_count"] == CFG.param_count()
        assert c["blocks_per_stage"] == CFG.blocks_per_stage

    def test_param_layout_offsets_contiguous(self, artifacts):
        _, manifest = artifacts
        for layout in manifest["param_layout"].values():
            offset = 0
            for t in layout:
                assert t["offset"] == offset
                assert t["elements"] == math.prod(t["shape"])
                offset += t["elements"]

    def test_body_layout_matches_artifact_inputs(self, artifacts):
        """body_fwd inputs = stage params (manifest order) + hidden state."""
        _, manifest = artifacts
        layout = manifest["param_layout"]["body_stage"]
        inputs = manifest["artifacts"]["body_fwd"]["inputs"]
        assert len(inputs) == len(layout) + 1
        for t, spec in zip(layout, inputs):
            assert spec["shape"] == t["shape"]
        assert inputs[-1]["shape"] == [CFG.microbatch, CFG.context, CFG.dim]

    def test_bwd_outputs_mirror_inputs(self, artifacts):
        _, manifest = artifacts
        a = manifest["artifacts"]
        # body_bwd: (gh, gparams...) mirrors (params..., h)
        fwd_in = a["body_fwd"]["inputs"]
        bwd_out = a["body_bwd"]["outputs"]
        assert bwd_out[0]["shape"] == fwd_in[-1]["shape"]
        assert [o["shape"] for o in bwd_out[1:]] == [i["shape"] for i in fwd_in[:-1]]

    def test_init_specs_present(self, artifacts):
        _, manifest = artifacts
        for layout in manifest["param_layout"].values():
            for t in layout:
                kind = t["init"]["kind"]
                assert kind in ("ones", "normal")
                if t["name"].endswith("norm"):
                    assert kind == "ones"

    def test_json_parses_from_disk(self, artifacts):
        cfg_dir, manifest = artifacts
        on_disk = json.loads((cfg_dir / "manifest.json").read_text())
        assert on_disk == json.loads(json.dumps(manifest))


class TestHloExecution:
    """Compile the HLO text locally and compare against eager execution."""

    @pytest.fixture(scope="class")
    def inputs(self):
        key = jax.random.PRNGKey(7)
        ids = jax.random.randint(key, (CFG.microbatch, CFG.context), 0, CFG.vocab)
        E, D, nw = init_embed_params(CFG, key)
        sp = init_stage_params(CFG, jax.random.PRNGKey(8))
        h = jax.random.normal(jax.random.PRNGKey(9), (CFG.microbatch, CFG.context, CFG.dim))
        return ids, (E, D, nw), sp, h

    def _run_hlo(self, cfg_dir, name, args):
        text = (cfg_dir / f"{name}.hlo.txt").read_text()
        client = jax.devices()[0].client
        # Text → HloModule → StableHLO → compile: the same parse-from-text
        # load path the Rust runtime uses (which goes text → proto →
        # XlaComputation through the xla crate instead).
        mod = xc._xla.hlo_module_from_text(text)
        shlo = xc._xla.mlir.hlo_to_stablehlo(mod.as_serialized_hlo_module_proto())
        exe = client.compile_and_load(shlo, client.devices())
        outs = exe.execute_sharded([jnp.asarray(a) for a in args])
        return [np.asarray(o[0]) for o in outs.disassemble_into_single_device_arrays()]

    @pytest.mark.parametrize("name", ["embed_fwd", "head_fwd", "body_fwd"])
    def test_hlo_matches_eager_fwd(self, artifacts, inputs, name):
        cfg_dir, _ = artifacts
        ids, (E, D, nw), sp, h = inputs
        eps = make_entry_points(CFG)
        args = {
            "embed_fwd": (E, ids),
            "head_fwd": (D, nw, h, ids),
            "body_fwd": (*sp, h),
        }[name]
        got = self._run_hlo(cfg_dir, name, args)
        want = eps[name][0](*args)
        assert len(got) == len(want)
        for g, w in zip(got, want):
            np.testing.assert_allclose(g, w, atol=1e-4, rtol=1e-4)

    def test_hlo_matches_eager_head_bwd(self, artifacts, inputs):
        cfg_dir, _ = artifacts
        ids, (E, D, nw), _, h = inputs
        eps = make_entry_points(CFG)
        got = self._run_hlo(cfg_dir, "head_bwd", (D, nw, h, ids))
        want = eps["head_bwd"][0](D, nw, h, ids)
        for g, w in zip(got, want):
            np.testing.assert_allclose(g, np.asarray(w), atol=1e-4, rtol=1e-4)

    def test_hlo_matches_eager_optimizer(self, artifacts, inputs):
        """The device-resident optimizer entries (grad accumulate + fused
        Adam) execute from HLO text exactly like their eager forms."""
        cfg_dir, _ = artifacts
        _, _, sp, _ = inputs
        eps = make_entry_points(CFG)

        g = [0.5 * x for x in sp]
        accum_args = (*sp, *g)
        got = self._run_hlo(cfg_dir, "body_grad_accum", accum_args)
        want = eps["body_grad_accum"][0](*accum_args)
        assert len(got) == len(want)
        for a, b in zip(got, want):
            np.testing.assert_allclose(a, np.asarray(b), atol=1e-5, rtol=1e-5)

        zeros = [jnp.zeros_like(x) for x in sp]
        sc = adam_scalars(t=1, lr=1e-3, microbatches=CFG.microbatch)
        adam_args = (*sp, *zeros, *zeros, *g, sc)
        got = self._run_hlo(cfg_dir, "body_adam", adam_args)
        want = eps["body_adam"][0](*adam_args)
        assert len(got) == len(want)
        for a, b in zip(got, want):
            np.testing.assert_allclose(a, np.asarray(b), atol=1e-5, rtol=1e-5)

    def test_hlo_text_has_no_mosaic_custom_calls(self, artifacts):
        """interpret=True must have lowered pallas to plain HLO."""
        cfg_dir, manifest = artifacts
        for art in manifest["artifacts"].values():
            text = (cfg_dir / art["file"]).read_text()
            assert "mosaic" not in text.lower(), art["file"]


class TestHloTextFormat:
    def test_to_hlo_text_is_parseable(self):
        lowered = jax.jit(lambda x: (x * 2,)).lower(
            jax.ShapeDtypeStruct((4,), jnp.float32)
        )
        text = to_hlo_text(lowered)
        assert "HloModule" in text
        mod = xc._xla.hlo_module_from_text(text)
        assert mod is not None
