//! **Table 2** — iteration time and train time of the four recovery
//! strategies at 5/10/16% hourly stage-failure rates, paper-scale
//! (500M model, 7 stages, 20 nodes, 5 GCP regions).
//!
//! Iteration times come from the mechanism simulator
//! ([`checkfree::sim`], calibrated only at the single baseline point
//! 91.3 s); train times combine the paper's converged-iteration counts
//! (Fig 3 x-axis) with the simulated iteration time + failure/rollback/
//! checkpoint overheads.
//!
//! ```bash
//! cargo run --release --example table2_throughput
//! ```

use checkfree::config::Strategy;
use checkfree::metrics::write_csv;
use checkfree::sim::{paper_converged_iterations, simulate_training, SimParams};
use checkfree::Result;

/// Paper Table 2 values for the comparison printout.
const PAPER: &[(&str, [f64; 3], [f64; 3])] = &[
    ("checkpointing", [91.4, 91.4, 92.1], [558.2, 621.7, 634.4]),
    ("redundant-comp", [151.0, 151.0, 151.0], [419.6, 419.6, 419.6]),
    ("checkfree", [91.3, 91.3, 92.1], [367.8, 405.9, 563.0]),
    ("checkfree+", [91.3, 91.3, 92.1], [355.1, 367.8, 460.6]),
];

fn main() -> Result<()> {
    let rates = [0.05, 0.10, 0.16];
    println!("Table 2 — throughput at paper scale (simulated testbed; see DESIGN.md §2)\n");
    println!(
        "{:<16} {:>6} {:>12} {:>12} {:>12} {:>12}",
        "strategy", "rate", "iter (s)", "paper", "train (h)", "paper"
    );
    let mut csv = String::from("strategy,rate,iter_s,paper_iter_s,train_h,paper_train_h\n");
    for (si, strategy) in [
        Strategy::Checkpoint,
        Strategy::Redundant,
        Strategy::CheckFree,
        Strategy::CheckFreePlus,
    ]
    .iter()
    .enumerate()
    {
        for (ri, &rate) in rates.iter().enumerate() {
            let p = SimParams::paper_medium(*strategy, rate);
            let run = simulate_training(&p, paper_converged_iterations(*strategy, rate));
            let (label, p_iter, p_train) = PAPER[si];
            println!(
                "{:<16} {:>5.0}% {:>12.1} {:>12.1} {:>12.1} {:>12.1}",
                label,
                rate * 100.0,
                run.iteration_seconds,
                p_iter[ri],
                run.train_hours,
                p_train[ri]
            );
            csv.push_str(&format!(
                "{label},{rate},{:.2},{},{:.2},{}\n",
                run.iteration_seconds, p_iter[ri], run.train_hours, p_train[ri]
            ));
        }
    }
    write_csv("results/table2_throughput.csv", &csv)?;

    // the paper's headline claim
    let cf = simulate_training(
        &SimParams::paper_medium(Strategy::CheckFree, 0.05),
        paper_converged_iterations(Strategy::CheckFree, 0.05),
    );
    let red = simulate_training(
        &SimParams::paper_medium(Strategy::Redundant, 0.05),
        paper_converged_iterations(Strategy::Redundant, 0.05),
    );
    println!(
        "\nheadline: CheckFree is {:.0}% faster than redundant computation at 5% churn (paper: >12%)",
        (red.train_hours / cf.train_hours - 1.0) * 100.0
    );
    println!("rows → results/table2_throughput.csv");
    Ok(())
}
