//! **Fig 2** — reinitialization-strategy ablation (paper §4.1, A.5):
//! random vs copy vs weighted gradient averaging for a lost stage, same
//! seed and the same forced failure schedule for all three.
//!
//! Paper finding to reproduce: weighted ≻ copy ≻ random (final loss).
//!
//! ```bash
//! cargo run --release --example fig2_init_strategies [-- iterations]
//! ```

use checkfree::experiments::fig2_init_strategies;
use checkfree::metrics::{comparison_csv, write_csv};
use checkfree::Result;

fn main() -> Result<()> {
    let iters: u64 = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(120);
    // periodic failures of alternating body stages (≈16% regime scaled)
    let failures: Vec<(u64, usize)> = (1..iters / 20).map(|k| (k * 20, 1 + (k as usize % 2))).collect();
    println!("Fig 2 — reinit strategies on 'e2e' model, {iters} iterations");
    println!("forced stage failures at: {failures:?}\n");

    let runs = fig2_init_strategies("e2e", iters, &failures, 42)?;

    println!("{:<10} {:>12} {:>12}", "strategy", "final train", "final val");
    for r in &runs {
        let last = r.curve.last().unwrap();
        println!(
            "{:<10} {:>12.4} {:>12.4}",
            r.label,
            last.train_loss,
            r.final_val_loss().unwrap_or(f32::NAN)
        );
    }
    let refs: Vec<&_> = runs.iter().collect();
    write_csv("results/fig2_init_strategies.csv", &comparison_csv(&refs, false))?;
    println!("\ncurves → results/fig2_init_strategies.csv");
    println!("expected ordering (paper Fig 2): weighted < copy < random");
    Ok(())
}
