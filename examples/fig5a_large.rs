//! **Fig 5a** — convergence of the largest model at 16% failure rate
//! (paper §5.2): redundant computation vs CheckFree vs CheckFree+.
//!
//! The paper's 1.5B model maps to this testbed's largest CPU-trainable
//! preset (`convergence`) at the most aggressive churn; the claim under
//! test is the *shape*: redundant converges faster per iteration, but
//! CheckFree(+) still converges and wins on (simulated) wall-clock.
//!
//! ```bash
//! cargo run --release --example fig5a_large [-- iterations]
//! ```

use checkfree::config::Strategy;
use checkfree::experiments::convergence_comparison;
use checkfree::metrics::{comparison_csv, write_csv};
use checkfree::Result;

fn main() -> Result<()> {
    let iters: u64 = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(150);
    let rate = 0.032; // 16%-per-hour regime scaled
    println!("Fig 5a — 'large' regime: e2e model @ rate {rate}, {iters} iters\n");

    let runs = convergence_comparison("e2e", iters, rate, 31415)?;
    println!("{:<28} {:>10} {:>9} {:>11}", "strategy", "final val", "failures", "sim-hours");
    for r in &runs {
        println!(
            "{:<28} {:>10.4} {:>9} {:>11.1}",
            r.label,
            r.final_val_loss().unwrap_or(f32::NAN),
            r.failures(),
            r.curve.last().map(|p| p.sim_time_s / 3600.0).unwrap_or(0.0)
        );
    }
    // wall-clock comparison at equal val loss: redundant pays 1.65×/iter
    let redundant = runs.iter().find(|r| r.label == Strategy::Redundant.label()).unwrap();
    let checkfree = runs.iter().find(|r| r.label == Strategy::CheckFree.label()).unwrap();
    if let (Some(rv), Some(cv)) = (redundant.final_val_loss(), checkfree.final_val_loss()) {
        let target = rv.max(cv) + 0.02;
        if let (Some(tr), Some(tc)) = (redundant.time_to_target(target), checkfree.time_to_target(target))
        {
            println!(
                "\ntime to val loss {target:.3}: redundant {:.1} sim-h vs checkfree {:.1} sim-h",
                tr / 3600.0,
                tc / 3600.0
            );
        }
    }
    let refs: Vec<&_> = runs.iter().collect();
    write_csv("results/fig5a_large.csv", &comparison_csv(&refs, true))?;
    println!("curves → results/fig5a_large.csv");
    println!("expected shape (paper Fig 5a): redundant faster per iteration, checkfree faster per wall-clock");
    Ok(())
}
