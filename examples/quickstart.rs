//! Quickstart: train a small LLaMa pipeline, kill a stage mid-run, watch
//! CheckFree recover it without a checkpoint.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use checkfree::config::{FailureSpec, Strategy, TrainConfig};
use checkfree::coordinator::Trainer;
use checkfree::metrics::write_csv;
use checkfree::Result;

fn main() -> Result<()> {
    let cfg = TrainConfig {
        model: "tiny".into(),
        strategy: Strategy::CheckFree,
        iterations: 40,
        microbatches_per_iter: 2,
        failure: FailureSpec::PerIteration { rate: 0.0 },
        eval_every: 4,
        seed: 7,
        ..TrainConfig::default()
    };
    println!("== checkfree quickstart ==");
    println!(
        "model '{}': training {} iterations, killing stage 1 at iteration 20\n",
        cfg.model, cfg.iterations
    );

    let mut trainer = Trainer::new(cfg)?;
    trainer.force_failure(20, 1);

    let summary = trainer.run()?;

    println!("iter   train-loss  val-loss   events");
    for p in &trainer.record.curve {
        let events: Vec<String> = trainer
            .record
            .events
            .iter()
            .filter(|e| e.iteration == p.iteration)
            .map(|e| format!("{}(S{})", e.kind.label(), e.stage.unwrap_or(99)))
            .collect();
        let val = p.val_loss.map(|v| format!("{v:.4}")).unwrap_or_else(|| "  -   ".into());
        println!("{:>4}   {:>9.4}   {val}   {}", p.iteration, p.train_loss, events.join(" "));
    }
    println!(
        "\nsummary: {} failures recovered, final val loss {:.4} (started ≈ ln(vocab) = {:.2})",
        summary.failures,
        summary.final_val_loss,
        (trainer.engine.runtime.manifest.config.vocab as f32).ln()
    );
    write_csv("results/quickstart.csv", &trainer.record.curve_csv())?;
    println!("loss curve written to results/quickstart.csv");
    Ok(())
}
