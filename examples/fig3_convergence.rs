//! **Fig 3** — convergence of the four recovery strategies under failures
//! (paper §5.2): loss vs iteration for (a) the small and (b) the medium
//! model at 10% failure rate, identical failure pattern across strategies.
//!
//! On this testbed "small"/"medium" map to the `tiny`/`convergence`
//! presets (DESIGN.md §2 substitutions) and the hourly rate maps to a
//! per-iteration rate chosen to give the same expected failures per run.
//!
//! ```bash
//! cargo run --release --example fig3_convergence [-- iterations [model]]
//! ```

use checkfree::experiments::convergence_comparison;
use checkfree::metrics::{comparison_csv, write_csv};
use checkfree::Result;

fn main() -> Result<()> {
    let iters: u64 = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(150);
    let models: Vec<String> = match std::env::args().nth(2) {
        Some(m) => vec![m],
        None => vec!["tiny".into(), "e2e".into()],
    };
    // ≈ paper's 10%/hour regime scaled to our run length: a handful of
    // failures per strategy per run.
    let rate = 0.02;

    for model in &models {
        println!("Fig 3 — {model} model, {iters} iterations, per-iteration failure rate {rate}");
        let runs = convergence_comparison(model, iters, rate, 1234)?;
        println!("{:<28} {:>10} {:>10} {:>9}", "strategy", "final val", "failures", "sim-h");
        for r in &runs {
            println!(
                "{:<28} {:>10.4} {:>10} {:>9.1}",
                r.label,
                r.final_val_loss().unwrap_or(f32::NAN),
                r.failures(),
                r.curve.last().map(|p| p.sim_time_s / 3600.0).unwrap_or(0.0)
            );
        }
        let refs: Vec<&_> = runs.iter().collect();
        let path = format!("results/fig3_convergence_{model}.csv");
        write_csv(&path, &comparison_csv(&refs, true))?;
        println!("curves → {path}\n");
    }
    println!("expected shape (paper Fig 3): redundant ≻ checkfree+ ≻ checkfree ≻ checkpointing per iteration");
    Ok(())
}
