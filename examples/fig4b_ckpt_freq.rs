//! **Fig 4b** — checkpointing-frequency ablation (paper §5.2): checkpoint
//! every 10 / 50 / 100 iterations vs CheckFree+, 10% failure regime.
//!
//! Paper finding: CheckFree+ beats even high-frequency checkpointing
//! because every failure still rolls the model back.
//!
//! ```bash
//! cargo run --release --example fig4b_ckpt_freq [-- iterations]
//! ```

use checkfree::experiments::checkpoint_freq_sweep;
use checkfree::metrics::{comparison_csv, write_csv};
use checkfree::Result;

fn main() -> Result<()> {
    let iters: u64 = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(150);
    let rate = 0.02;
    // paper sweeps 10/50/100 over ~20k iterations; scaled to our length.
    let periods = [5u64, 15, 40];
    println!("Fig 4b — checkpoint cadences {periods:?} vs CheckFree+ (rate {rate}), {iters} iters\n");

    let runs = checkpoint_freq_sweep("e2e", iters, rate, &periods, 2024)?;
    println!("{:<16} {:>10} {:>10} {:>10}", "run", "final val", "failures", "rollbacks");
    for r in &runs {
        let rollbacks = r
            .events
            .iter()
            .filter(|e| e.kind == checkfree::metrics::EventKind::Rollback)
            .count();
        println!(
            "{:<16} {:>10.4} {:>10} {:>10}",
            r.label,
            r.final_val_loss().unwrap_or(f32::NAN),
            r.failures(),
            rollbacks
        );
    }
    let refs: Vec<&_> = runs.iter().collect();
    write_csv("results/fig4b_ckpt_freq.csv", &comparison_csv(&refs, true))?;
    println!("\ncurves → results/fig4b_ckpt_freq.csv");
    println!("expected shape (paper Fig 4b): checkfree+ below every cadence, incl. the densest");
    Ok(())
}
