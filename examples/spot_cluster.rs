//! **End-to-end driver** — the full system on a realistic spot-cluster
//! scenario: a multi-million-parameter LLaMa pipeline trained for a few
//! hundred iterations on the synthetic corpus while spot instances churn,
//! with CheckFree+ recovering every lost stage and the loss curve logged
//! throughout. All three layers compose here: Pallas kernels → JAX stage
//! graphs → AOT HLO → Rust PJRT runtime → coordinator/recovery.
//!
//! ```bash
//! cargo run --release --example spot_cluster \
//!     [-- iterations [model [churn-process [trace]]]]
//! # model: e2e (default, 8 layers), convergence (12 layers)
//! # churn-process: bernoulli (default) | poisson | bursty | correlated
//! # trace: record:<path> — write this run's churn tape (JSONL);
//! #        replay:<path> — re-run an existing tape verbatim, e.g. the
//! #        committed examples/traces/spot_burst.jsonl, so every
//! #        strategy/config change is compared on the same churn
//! ```
//!
//! The run is recorded in EXPERIMENTS.md §End-to-end.

use std::time::Instant;

use checkfree::config::{FailureSpec, Strategy, TraceMode, TrainConfig};
use checkfree::coordinator::Trainer;
use checkfree::failures::ChurnProcessKind;
use checkfree::metrics::write_csv;
use checkfree::Result;

fn main() -> Result<()> {
    let iters: u64 = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(300);
    let model = std::env::args().nth(2).unwrap_or_else(|| "e2e".into());
    let churn: ChurnProcessKind = std::env::args()
        .nth(3)
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(ChurnProcessKind::Bernoulli);
    let trace: Option<TraceMode> = std::env::args().nth(4).map(|s| s.parse()).transpose()?;
    let cfg = TrainConfig {
        model: model.clone(),
        strategy: Strategy::CheckFreePlus,
        iterations: iters,
        microbatches_per_iter: 4,
        failure: FailureSpec::PerIteration { rate: 0.01 },
        eval_every: 10,
        seed: 20250710,
        churn_process: churn,
        churn_trace: trace.clone(),
        ..TrainConfig::default()
    };
    let mut trainer = Trainer::new(cfg)?;
    let mc = trainer.engine.runtime.manifest.config.clone();
    println!("== spot-cluster end-to-end driver ==");
    println!(
        "model '{}': {:.1}M params, {} stages ({} body × {} blocks), ctx {}, vocab {}",
        mc.name,
        mc.param_count as f64 / 1e6,
        mc.body_stages + 1,
        mc.body_stages,
        mc.blocks_per_stage,
        mc.context,
        mc.vocab
    );
    match &trace {
        Some(TraceMode::Replay(path)) => {
            println!("strategy checkfree+ | churn tape {path} (replay) | {iters} iterations\n")
        }
        Some(TraceMode::Record(path)) => println!(
            "strategy checkfree+ | churn {} 1%/stage/iter → {path} | {iters} iterations\n",
            churn.label()
        ),
        None => println!(
            "strategy checkfree+ | churn {} 1%/stage/iter | {iters} iterations\n",
            churn.label()
        ),
    }

    let wall = Instant::now();
    let mut last_report = Instant::now();
    for _ in 0..iters {
        let loss = trainer.step()?;
        let it = trainer.global_step();
        if it % 10 == 0 || last_report.elapsed().as_secs() > 20 {
            let val = trainer
                .record
                .curve
                .last()
                .and_then(|p| p.val_loss)
                .map(|v| format!("val {v:.4}"))
                .unwrap_or_default();
            println!(
                "iter {it:>4}  loss {loss:.4}  {val}  [{:.1}s wall, {} failures]",
                wall.elapsed().as_secs_f64(),
                trainer.record.failures()
            );
            last_report = Instant::now();
        }
    }
    let wall_s = wall.elapsed().as_secs_f64();

    let first = trainer.record.curve.first().unwrap().train_loss;
    let final_val = trainer.engine.validate()?;
    println!("\n== summary ==");
    println!("wall time: {wall_s:.1}s ({:.2} s/iter)", wall_s / iters as f64);
    println!("loss: {first:.4} → {final_val:.4} (val), ln(V) = {:.3}", (mc.vocab as f32).ln());
    println!(
        "failures survived: {} (recovery events: {})",
        trainer.record.failures(),
        trainer
            .record
            .events
            .iter()
            .filter(|e| e.kind == checkfree::metrics::EventKind::Recovery)
            .count()
    );
    println!("simulated geo-distributed wall-clock: {:.1} h", trainer.sim_time_s() / 3600.0);
    // per-executable PJRT accounting (perf visibility)
    println!("\nPJRT executable time:");
    for (name, dur, calls) in trainer.engine.runtime.exec_stats() {
        println!("  {name:<10} {calls:>6} calls  {:>8.2}s", dur.as_secs_f64());
    }
    let path = format!("results/spot_cluster_{model}.csv");
    write_csv(&path, &trainer.record.curve_csv())?;
    write_csv(
        &format!("results/spot_cluster_{model}.events.csv"),
        &trainer.record.events_csv(),
    )?;
    println!("\nloss curve → {path}");
    assert!(
        final_val < first - 1.0,
        "E2E driver must show real convergence (got {first:.3} → {final_val:.3})"
    );
    Ok(())
}
