//! **Table 3** — evaluation perplexity of a model trained with CheckFree
//! (with failures) vs redundant computation (≡ fault-free training),
//! both to the SAME iteration count, across four evaluation domains.
//!
//! The paper's OpenWebText / Common Crawl / Stack Exchange / Arxiv map to
//! the synthetic `stories` (in-domain) / `web` / `qa` / `arxiv` domains
//! (DESIGN.md §2). The shape under test: near-par perplexity despite
//! drastically different resultant weights.
//!
//! ```bash
//! cargo run --release --example table3_perplexity [-- iterations]
//! ```

use checkfree::experiments::perplexity_comparison;
use checkfree::metrics::write_csv;
use checkfree::Result;

fn main() -> Result<()> {
    let iters: u64 = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(200);
    let rate = 0.02;
    println!("Table 3 — perplexity after {iters} equal iterations ('e2e' model)\n");

    let rows = perplexity_comparison("e2e", iters, rate, 777)?;
    println!("{:<22} {:>12} {:>12} {:>8}", "domain", "redundant", "checkfree", "Δ%");
    let mut csv = String::from("domain,redundant,checkfree\n");
    for r in &rows {
        println!(
            "{:<22} {:>12.3} {:>12.3} {:>7.1}%",
            r.domain,
            r.redundant,
            r.checkfree,
            (r.checkfree / r.redundant - 1.0) * 100.0
        );
        csv.push_str(&format!("{},{:.4},{:.4}\n", r.domain, r.redundant, r.checkfree));
    }
    write_csv("results/table3_perplexity.csv", &csv)?;
    println!("\nrows → results/table3_perplexity.csv");
    println!("expected shape (paper Table 3): near-par perplexity; redundant edges out out-of-domain");
    Ok(())
}
