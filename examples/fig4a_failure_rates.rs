//! **Fig 4a** — CheckFree+ convergence at varying failure frequencies
//! (paper §5.2): 5%, 10%, 16% hourly rates on the medium model, scaled to
//! per-iteration rates on this testbed.
//!
//! Paper finding: performance degrades only mildly as the rate triples.
//!
//! ```bash
//! cargo run --release --example fig4a_failure_rates [-- iterations]
//! ```

use checkfree::experiments::failure_rate_sweep;
use checkfree::metrics::{comparison_csv, write_csv};
use checkfree::Result;

fn main() -> Result<()> {
    let iters: u64 = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(150);
    // 5/10/16%-per-hour scaled to per-iteration probabilities that give
    // the same expected failure count over the run as the paper's setup.
    let rates = [0.01, 0.02, 0.032];
    println!("Fig 4a — CheckFree+ on 'e2e' model at rates {rates:?}, {iters} iters\n");

    let runs = failure_rate_sweep("e2e", iters, &rates, 99)?;
    println!("{:<8} {:>10} {:>10}", "rate", "final val", "failures");
    for r in &runs {
        println!(
            "{:<8} {:>10.4} {:>10}",
            r.label,
            r.final_val_loss().unwrap_or(f32::NAN),
            r.failures()
        );
    }
    let refs: Vec<&_> = runs.iter().collect();
    write_csv("results/fig4a_failure_rates.csv", &comparison_csv(&refs, true))?;
    println!("\ncurves → results/fig4a_failure_rates.csv");
    println!("expected shape (paper Fig 4a): mild degradation as rate triples");
    Ok(())
}
