//! **Fig 5b** — the cost of out-of-order swapping with no failures
//! (paper §5.2): CheckFree+ (swaps on) vs standard training, 0% failure.
//!
//! Paper finding: a visible convergence slowdown from swapping — the
//! price CheckFree+ pays for first/last-stage recoverability.
//!
//! ```bash
//! cargo run --release --example fig5b_swap_overhead [-- iterations]
//! ```

use checkfree::experiments::swap_overhead;
use checkfree::metrics::{comparison_csv, write_csv};
use checkfree::Result;

fn main() -> Result<()> {
    let iters: u64 = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(150);
    println!("Fig 5b — swap overhead at 0% failures, 'e2e' model, {iters} iters\n");

    let runs = swap_overhead("e2e", iters, 2718)?;
    println!("{:<26} {:>12} {:>12}", "schedule", "final train", "final val");
    for r in &runs {
        let last = r.curve.last().unwrap();
        println!(
            "{:<26} {:>12.4} {:>12.4}",
            r.label,
            last.train_loss,
            r.final_val_loss().unwrap_or(f32::NAN)
        );
    }
    let refs: Vec<&_> = runs.iter().collect();
    write_csv("results/fig5b_swap_overhead.csv", &comparison_csv(&refs, false))?;
    println!("\ncurves → results/fig5b_swap_overhead.csv");
    println!("expected shape (paper Fig 5b): with-swaps converges more slowly");
    Ok(())
}
