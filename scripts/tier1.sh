#!/usr/bin/env bash
# Tier-1 verification (see ROADMAP.md): release build + test suite +
# clippy + docs/format gate + a smoke train_iteration timing check that
# also refreshes BENCH_hot_path.json.
#
# Usage: scripts/tier1.sh [--no-smoke] [--docs]
#   --no-smoke  skip the timing smoke run
#   --docs      run ONLY the documentation/format gate (fast local check)
set -euo pipefail

repo_root="$(cd "$(dirname "$0")/.." && pwd)"
cd "$repo_root/rust"

if ! command -v cargo >/dev/null 2>&1; then
    echo "error: cargo not found on PATH — this container lacks the Rust toolchain." >&2
    echo "       Run tier-1 in the rust_pallas toolchain image (needs cargo + vendored" >&2
    echo "       'anyhow' and 'xla' crates + PJRT CPU plugin; see rust/Cargo.toml)." >&2
    exit 1
fi

docs_gate() {
    echo "== cargo doc --no-deps (deny rustdoc warnings) =="
    RUSTDOCFLAGS="-D warnings" cargo doc --no-deps
    echo "== cargo fmt --check =="
    if cargo fmt --version >/dev/null 2>&1; then
        cargo fmt --all -- --check
    else
        echo "rustfmt unavailable; skipping format gate" >&2
    fi
}

if [[ "${1:-}" == "--docs" ]]; then
    docs_gate
    echo "docs gate OK"
    exit 0
fi

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

echo "== cargo clippy (deny warnings) =="
if cargo clippy --version >/dev/null 2>&1; then
    cargo clippy --all-targets -- -D warnings
else
    echo "clippy unavailable; skipping lint gate" >&2
fi

docs_gate

if [[ "${1:-}" != "--no-smoke" ]]; then
    echo "== smoke train_iteration timing (tiny, 4 microbatches, seq vs pipelined vs 1F1B) =="
    cargo bench --bench hot_path -- --smoke
    echo "Smoke results in BENCH_hot_path.smoke.json (gitignored); run the full"
    echo "'cargo bench --bench hot_path' to refresh the committed BENCH_hot_path.json."
fi

echo "tier-1 OK"
