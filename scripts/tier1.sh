#!/usr/bin/env bash
# Tier-1 verification (see ROADMAP.md): release build + test suite +
# clippy gate + docs/format/bench-schema gate + a smoke train_iteration
# timing check.
#
# Usage: scripts/tier1.sh [--ci] [--no-smoke] [--docs] [--clippy]
#                         [--bench-smoke] [--recovery-smoke]
#                         [--coverage-smoke] [--transport-smoke]
#   --ci           CI mode: `set -x` tracing, plus one machine-readable
#                  `tier1-gate <name>=pass|fail` line per gate (and a
#                  markdown row in the GitHub step summary when
#                  $GITHUB_STEP_SUMMARY is set — summary emission is a
#                  strict no-op otherwise, so --ci works locally). Local
#                  output is unchanged without the flag.
#   --no-smoke     skip the timing smoke run
#   --docs         run ONLY the documentation/format/bench-schema gate
#   --clippy       run ONLY the clippy lint gate
#   --bench-smoke  run ONLY the hot-path bench at toy size (tiny model,
#                  short budgets) — catches bench bit-rot without waiting
#                  for the full measurement run; writes the gitignored
#                  BENCH_hot_path.smoke.json, never the committed file
#   --recovery-smoke  run ONLY the recovery-latency bench at toy budget;
#                  writes the gitignored BENCH_recovery.smoke.json (the
#                  CI recovery-smoke lane uploads it as an artifact)
#   --coverage-smoke  run ONLY the coverage-matrix bench at smoke
#                  budget (300 iterations/cell, same 36-cell shape up
#                  to 1024 stages); writes the gitignored
#                  BENCH_coverage.smoke.json (the nightly
#                  coverage-matrix CI lane runs the full version)
#   --transport-smoke  run ONLY the wire-transport lane: the
#                  integration suite with CHECKFREE_LINK_TRANSPORT=
#                  tcp-loopback (every cross-plane copy framed over a
#                  real socket), then the multi-process kill test —
#                  stage processes spawned from the built binary, one
#                  SIGKILLed mid-run, recovery over the healed wire,
#                  loss bitwise-equal to the in-process reference (the
#                  CI multi-process-smoke lane runs exactly this)
#
# Plane-mode matrix: the test suite honours CHECKFREE_PLANE_MODE
# (shared|per-stage) — TrainConfig::default() reads it — which is how
# .github/workflows/tier1.yml runs tier-1 under both PJRT plane
# layouts; CHECKFREE_LINK_TRANSPORT (in-process|tcp-loopback) does the
# same for the wire transport.
set -euo pipefail

repo_root="$(cd "$(dirname "$0")/.." && pwd)"

ci=0
only=""
no_smoke=0
for arg in "$@"; do
    case "$arg" in
    --ci) ci=1 ;;
    --docs) only=docs ;;
    --clippy) only=clippy ;;
    --bench-smoke) only=bench-smoke ;;
    --recovery-smoke) only=recovery-smoke ;;
    --coverage-smoke) only=coverage-smoke ;;
    --transport-smoke) only=transport-smoke ;;
    --no-smoke) no_smoke=1 ;;
    *)
        echo "unknown flag '$arg' (see scripts/tier1.sh header)" >&2
        exit 2
        ;;
    esac
done

# THE one place step-summary markdown leaves this script. A strict no-op
# when $GITHUB_STEP_SUMMARY is unset or empty (running `--ci` locally),
# and tolerant of an unwritable path (a stale value exported into a
# local shell must not abort the gates under `set -e`).
step_summary() { # <markdown line...>
    if [[ -n "${GITHUB_STEP_SUMMARY:-}" ]]; then
        # Group redirection inside braces so a failed open (stale path
        # exported into a local shell) is silenced too, not just the
        # command's own stderr.
        { printf '%s\n' "$@" >>"$GITHUB_STEP_SUMMARY"; } 2>/dev/null || true
    fi
}

# Emit the machine-readable per-gate verdict (CI mode only). Quieted
# around `set -x` so the summary lines stay greppable in the trace.
report_gate() { # <name> <pass|fail>
    if [[ $ci -eq 1 ]]; then
        { set +x; } 2>/dev/null
        echo "tier1-gate $1=$2"
        local icon="✅"
        [[ "$2" == fail ]] && icon="❌"
        step_summary "| $1 | $icon $2 |"
        set -x
    fi
}

# Run one named gate; on failure report it before exiting (set -e).
gate() { # <name> <command...>
    local name="$1"
    shift
    if "$@"; then
        report_gate "$name" pass
    else
        local rc=$?
        report_gate "$name" fail
        exit "$rc"
    fi
}

if [[ $ci -eq 1 ]]; then
    step_summary "### tier-1 gates" "| gate | result |" "|---|---|"
    set -x
fi

# NOTE: gate functions run inside `gate`'s `if` condition, where bash
# ignores errexit — every step chains `|| return 1` explicitly so a
# failing early step cannot be masked by a passing later one.

# The bench-schema check is pure python stdlib — it must work (and is
# exercised by CI) even in a cargo-less container. The --selftest pass
# runs first: it proves the checker rejects the bad-wait fixture, so a
# green schema gate means the overlap gate has teeth, not just that
# the committed files happen to parse.
schema_gate() {
    echo "== bench JSON schema check =="
    if command -v python3 >/dev/null 2>&1; then
        python3 "$repo_root/scripts/check_bench_json.py" --selftest || return 1
        python3 "$repo_root/scripts/check_bench_json.py" || return 1
    else
        echo "python3 unavailable; skipping bench-schema gate" >&2
    fi
}

docs_gate() {
    echo "== cargo doc --no-deps (deny rustdoc warnings) =="
    RUSTDOCFLAGS="-D warnings" cargo doc --no-deps || return 1
    echo "== cargo fmt --check =="
    if cargo fmt --version >/dev/null 2>&1; then
        cargo fmt --all -- --check || return 1
    else
        echo "rustfmt unavailable; skipping format gate" >&2
    fi
    schema_gate || return 1
}

clippy_gate() {
    echo "== cargo clippy --all-targets (deny warnings) =="
    if cargo clippy --version >/dev/null 2>&1; then
        cargo clippy --all-targets -- -D warnings || return 1
    else
        echo "clippy unavailable; skipping lint gate" >&2
    fi
}

bench_smoke() {
    echo "== smoke hot-path bench (tiny, short budgets: timings + watermark + device-residency sections) =="
    cargo bench --bench hot_path -- --smoke || return 1
    echo "Smoke results in BENCH_hot_path.smoke.json (gitignored); run the full"
    echo "'cargo bench --bench hot_path' to refresh the committed BENCH_hot_path.json."
}

recovery_smoke() {
    echo "== smoke recovery-latency bench (short budgets: simulated latencies + netsim micro-benches) =="
    cargo bench --bench recovery_latency -- --smoke || return 1
    echo "Smoke results in BENCH_recovery.smoke.json (gitignored); run the full"
    echo "'cargo bench --bench recovery_latency' to refresh the committed BENCH_recovery.json."
}

coverage_smoke() {
    echo "== smoke coverage-matrix bench (strategy x churn process x scale, 300 iters/cell) =="
    cargo bench --bench coverage_matrix -- --smoke || return 1
    echo "Smoke results in BENCH_coverage.smoke.json (gitignored); run the full"
    echo "'cargo bench --bench coverage_matrix' to refresh the committed BENCH_coverage.json."
}

transport_smoke() {
    echo "== integration suite over the tcp-loopback transport (every cross-plane copy framed over a socket) =="
    CHECKFREE_LINK_TRANSPORT=tcp-loopback cargo test -q --test integration || return 1
    echo "== multi-process lane: real stage processes, SIGKILL mid-run, recovery over the healed wire =="
    cargo test -q --test integration multi_process_cluster_survives_a_real_process_kill \
        -- --exact --nocapture || return 1
}

cd "$repo_root/rust"

if ! command -v cargo >/dev/null 2>&1; then
    echo "error: cargo not found on PATH — this container lacks the Rust toolchain." >&2
    echo "       Run tier-1 in the rust_pallas toolchain image (needs cargo + vendored" >&2
    echo "       'anyhow' and 'xla' crates + PJRT CPU plugin; see rust/Cargo.toml)." >&2
    report_gate toolchain fail
    exit 1
fi

case "$only" in
docs)
    gate docs docs_gate
    echo "docs gate OK"
    exit 0
    ;;
clippy)
    gate clippy clippy_gate
    echo "clippy gate OK"
    exit 0
    ;;
bench-smoke)
    gate bench-smoke bench_smoke
    echo "bench smoke OK"
    exit 0
    ;;
recovery-smoke)
    gate recovery-smoke recovery_smoke
    echo "recovery smoke OK"
    exit 0
    ;;
coverage-smoke)
    gate coverage-smoke coverage_smoke
    echo "coverage smoke OK"
    exit 0
    ;;
transport-smoke)
    gate transport-smoke transport_smoke
    echo "transport smoke OK"
    exit 0
    ;;
esac

echo "== cargo build --release =="
gate build cargo build --release

echo "== cargo test -q =="
gate test cargo test -q

gate clippy clippy_gate

gate docs docs_gate

if [[ $no_smoke -eq 0 ]]; then
    gate bench-smoke bench_smoke
fi

echo "tier-1 OK"
