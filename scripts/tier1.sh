#!/usr/bin/env bash
# Tier-1 verification (see ROADMAP.md): release build + test suite +
# clippy gate + docs/format gate + a smoke train_iteration timing check.
#
# Usage: scripts/tier1.sh [--no-smoke] [--docs] [--clippy] [--bench-smoke]
#   --no-smoke     skip the timing smoke run
#   --docs         run ONLY the documentation/format gate (fast local check)
#   --clippy       run ONLY the clippy lint gate
#   --bench-smoke  run ONLY the hot-path bench at toy size (tiny model,
#                  short budgets) — catches bench bit-rot without waiting
#                  for the full measurement run; writes the gitignored
#                  BENCH_hot_path.smoke.json, never the committed file
set -euo pipefail

repo_root="$(cd "$(dirname "$0")/.." && pwd)"
cd "$repo_root/rust"

if ! command -v cargo >/dev/null 2>&1; then
    echo "error: cargo not found on PATH — this container lacks the Rust toolchain." >&2
    echo "       Run tier-1 in the rust_pallas toolchain image (needs cargo + vendored" >&2
    echo "       'anyhow' and 'xla' crates + PJRT CPU plugin; see rust/Cargo.toml)." >&2
    exit 1
fi

docs_gate() {
    echo "== cargo doc --no-deps (deny rustdoc warnings) =="
    RUSTDOCFLAGS="-D warnings" cargo doc --no-deps
    echo "== cargo fmt --check =="
    if cargo fmt --version >/dev/null 2>&1; then
        cargo fmt --all -- --check
    else
        echo "rustfmt unavailable; skipping format gate" >&2
    fi
}

clippy_gate() {
    echo "== cargo clippy --all-targets (deny warnings) =="
    if cargo clippy --version >/dev/null 2>&1; then
        cargo clippy --all-targets -- -D warnings
    else
        echo "clippy unavailable; skipping lint gate" >&2
    fi
}

bench_smoke() {
    echo "== smoke hot-path bench (tiny, short budgets: timings + watermark + device-residency sections) =="
    cargo bench --bench hot_path -- --smoke
    echo "Smoke results in BENCH_hot_path.smoke.json (gitignored); run the full"
    echo "'cargo bench --bench hot_path' to refresh the committed BENCH_hot_path.json."
}

case "${1:-}" in
--docs)
    docs_gate
    echo "docs gate OK"
    exit 0
    ;;
--clippy)
    clippy_gate
    echo "clippy gate OK"
    exit 0
    ;;
--bench-smoke)
    bench_smoke
    echo "bench smoke OK"
    exit 0
    ;;
esac

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

clippy_gate

docs_gate

if [[ "${1:-}" != "--no-smoke" ]]; then
    bench_smoke
fi

echo "tier-1 OK"
