#!/usr/bin/env python3
"""Validate committed BENCH_*.json files against docs/BENCHMARKS.md.

Stdlib-only on purpose: this runs in CI containers that have no cargo
(and no pip), so bench bit-rot is caught even where the benches cannot
be executed. Checked invariants:

* every file parses and declares ``bench``/``schema``/``status``;
* ``status`` is ``measured`` or ``pending-toolchain`` (placeholders must
  carry a ``note`` naming the gate the first toolchain run confirms);
* a file claiming ``status: "measured"`` must actually contain its gate
  sections — non-empty speedups, per-model watermark and residency
  entries with every documented field (``link_copies``/``link_bytes``
  since schema 2; ``link_direct``/``link_staged``/``donated_buffers``
  since schema 3; ``link_overlapped``/``link_blocking``/``link_wait_ns``
  since schema 4) — and every ``gate_*`` boolean must be true;
* at schema >= 3, a measured ``pipelined-1f1b-per-stage`` residency row
  with a nonzero ``link_staged`` column fails outright: per-stage mode
  on this testbed must take the direct link path, and a silently
  degraded run must not be committable as measured;
* at schema >= 4, every measured residency row must satisfy
  ``link_overlapped + link_blocking == link_copies`` (the overlap split
  is a partition, not a sample), and the ``plane_mode`` section must
  carry per-stage ``link_wait_ns_overlap_on`` / ``link_wait_ns_overlap_off``
  arrays where every stage with any link wait at all waits strictly
  less with prefetch on — a measured per-stage row where overlap on is
  not below overlap off fails outright (both-zero stages are skipped:
  they moved no cross-plane bytes);
* at schema >= 5, transfer rows gain the ``param_pulls`` column and the
  file must carry an ``optimizer_path`` section with per-model
  ``host``/``device`` transfer rows and timings; a measured device row
  must show ``param_pulls == 0`` (boundary pulls never belong to a
  steady-state iteration), ``host_syncs == microbatches*4`` (the m·L·P
  gradient-pull term is gone), and strictly fewer host syncs than the
  host-optimizer row — anything else means the fused on-plane Adam
  silently degraded and the run must not be committable as measured;
* at schema >= 6, transfer rows gain ``link_wire_bytes``/``link_wire_ns``
  and the file must carry a ``transport`` section with per-model
  ``in-process``/``tcp-loopback`` transfer rows: a measured tcp row with
  zero ``link_wire_bytes`` fails outright (the wire transport silently
  fell back to in-process links), as does a tcp row whose frames are not
  strictly larger than their payloads (CFW1 headers) or an in-process
  row billing any wire traffic at all; the ``shaped`` subsection's
  per-link rows are checked against the netsim floor recomputed HERE
  from this file's own copy of the gcp-5region latency matrix — a
  measured link whose ``mean_link_ns`` sits below ``scale`` x the
  one-way latency for its region pair beat physics and fails outright
  (the recorded ``floor_ns`` is never trusted);
* ``BENCH_recovery.json`` (and the gitignored ``BENCH_recovery.smoke``
  sidecar, when present) analogously for its latency table; at schema
  >= 2 a measured recovery file must carry the ``policy`` section (the
  burst_storm tape replay) with non-empty per-strategy runs, and the
  checker recomputes both gates from the raw runs rather than trusting
  the self-reported booleans: the adaptive policy's wall-clock must be
  strictly below every static strategy's, and the tiercheck run must
  show zero restore storage bytes;
* ``BENCH_coverage.json`` (the scenario-factory coverage matrix): a
  measured run must contain exactly |scales| x |strategies| x
  |churn_processes| cells, each with every documented field, a max
  scale >= 1024 (the thousand-stage scale-out is the artifact's whole
  point), per-cell sanity (``sampled_iterations <= iterations``,
  ``recoveries <= failures``), and all ``gate_*`` booleans true.

Exit status: 0 = all files valid, 1 = any violation (listed on stderr).

Usage: check_bench_json.py [FILE...]    (default: BENCH_*.json at the
repo root, including the gitignored smoke sidecars when present)
       check_bench_json.py --selftest   (validate the checker itself
against the committed good/bad fixtures in scripts/fixtures/)
"""

from __future__ import annotations

import json
import sys
from pathlib import Path

TRANSFER_FIELDS_V1 = (
    "host_syncs",
    "uploads",
    "bytes_down",
    "bytes_up",
    "forced_tuple_roundtrips",
)
TRANSFER_FIELDS_V2 = TRANSFER_FIELDS_V1 + ("link_copies", "link_bytes")
TRANSFER_FIELDS_V3 = TRANSFER_FIELDS_V2 + (
    "link_direct",
    "link_staged",
    "donated_buffers",
)
TRANSFER_FIELDS_V4 = TRANSFER_FIELDS_V3 + (
    "link_overlapped",
    "link_blocking",
    "link_wait_ns",
)
TRANSFER_FIELDS_V5 = TRANSFER_FIELDS_V4 + ("param_pulls",)
TRANSFER_FIELDS_V6 = TRANSFER_FIELDS_V5 + ("link_wire_bytes", "link_wire_ns")

# Mirror of rust/src/netsim/mod.rs::LATENCY_MS — kept in sync by the
# shaped-floor selftest fixtures. The checker recomputes every shaped
# link's floor from this table instead of trusting the bench's recorded
# ``floor_ns``, so a bench whose shaper quietly under-delays cannot
# certify itself.
WAN_REGIONS = (
    "us-central1",
    "us-east1",
    "europe-west4",
    "asia-east1",
    "australia-southeast1",
)
WAN_LATENCY_MS = (
    (0.5, 32.0, 103.0, 118.0, 176.0),
    (32.0, 0.5, 93.0, 152.0, 198.0),
    (103.0, 93.0, 0.5, 252.0, 277.0),
    (118.0, 152.0, 252.0, 0.5, 131.0),
    (176.0, 198.0, 277.0, 131.0, 0.5),
)

OPTIMIZER_PATH_FIELDS_V5 = (
    "host_mean_s",
    "device_mean_s",
    "device_over_host",
    "gate_device_syncs_m4_below_host",
)

PLANE_MODE_FIELDS_V4 = (
    "link_wait_ns_overlap_on",
    "link_wait_ns_overlap_off",
    "gate_overlap_wait_below_off",
)

WATERMARK_FIELDS = (
    "fill_drain",
    "one_f_one_b",
    "depth_bound",
    "gate_1f1b_below_fill_drain",
)

RESIDENCY_MODES_V1 = (
    "sequential",
    "pipelined",
    "pipelined-1f1b",
    "pipelined-1f1b-host-staging",
)
RESIDENCY_MODES_V2 = RESIDENCY_MODES_V1 + ("pipelined-1f1b-per-stage",)

LATENCY_FIELDS = (
    "scale",
    "stage_bytes",
    "model_bytes",
    "checkfree_worst_s",
    "ckpt_download_s",
    "ckpt_upload_s",
)

POLICY_RUN_FIELDS = (
    "strategy",
    "wall_clock_s",
    "failures",
    "rollback_iterations",
    "extra_convergence_iterations",
    "storage_bytes",
    "tier_backup_bytes",
    "restore_storage_bytes",
)

COVERAGE_CELL_FIELDS = (
    "strategy",
    "churn_process",
    "stages",
    "allow_adjacent",
    "rate_per_stage",
    "iterations",
    "failures",
    "recoveries",
    "rollback_iterations",
    "recovery_seconds",
    "checkpoint_stall_seconds",
    "sim_hours",
    "sampled_iterations",
    "wall_ms",
)

# The scale-out floor a measured coverage matrix must reach.
COVERAGE_MIN_TOP_SCALE = 1024


class Checker:
    def __init__(self, path: Path) -> None:
        self.path = path
        self.errors: list[str] = []

    def error(self, msg: str) -> None:
        self.errors.append(f"{self.path}: {msg}")

    def require(self, obj: dict, key: str, kinds, where: str = "top level"):
        """Presence + type check; returns the value (None when absent)."""
        if key not in obj:
            self.error(f"missing '{key}' at {where}")
            return None
        value = obj[key]
        if not isinstance(value, kinds):
            self.error(f"'{key}' at {where} has type {type(value).__name__}")
            return None
        return value

    def check_gates_true(self, obj: dict, where: str) -> None:
        for key, value in obj.items():
            if key.startswith("gate_") and value is not True:
                self.error(f"{where}.{key} is {value!r} — a committed measured "
                           "run must pass its gates (see docs/BENCHMARKS.md)")

    def check(self) -> None:
        try:
            doc = json.loads(self.path.read_text())
        except (OSError, json.JSONDecodeError) as exc:
            self.error(f"unreadable or invalid JSON: {exc}")
            return
        if not isinstance(doc, dict):
            self.error("top level is not an object")
            return

        bench = self.require(doc, "bench", str)
        schema = self.require(doc, "schema", (int, float))
        status = self.require(doc, "status", str)
        if status not in (None, "measured", "pending-toolchain"):
            self.error(f"unknown status {status!r}")
        if status == "pending-toolchain" and not doc.get("note"):
            self.error("pending-toolchain placeholder must carry a 'note' "
                       "naming the gate the first toolchain run confirms")

        if bench == "hot_path":
            self.check_hot_path(doc, status, schema or 0)
        elif bench == "recovery":
            self.check_recovery(doc, status, schema or 0)
        elif bench == "coverage":
            self.check_coverage(doc, status)
        elif bench is not None:
            self.error(f"unknown bench {bench!r}")

    def check_hot_path(self, doc: dict, status, schema) -> None:
        for key in ("pipelined_speedup", "pipelined_1f1b_speedup",
                    "activation_watermark", "device_residency"):
            self.require(doc, key, dict)
        self.require(doc, "results", list)
        if schema >= 6:
            self.require(doc, "transport", dict)
        if status != "measured":
            return

        if schema >= 6:
            transfer_fields = TRANSFER_FIELDS_V6
        elif schema >= 5:
            transfer_fields = TRANSFER_FIELDS_V5
        elif schema >= 4:
            transfer_fields = TRANSFER_FIELDS_V4
        elif schema >= 3:
            transfer_fields = TRANSFER_FIELDS_V3
        elif schema >= 2:
            transfer_fields = TRANSFER_FIELDS_V2
        else:
            transfer_fields = TRANSFER_FIELDS_V1
        residency_modes = RESIDENCY_MODES_V2 if schema >= 2 else RESIDENCY_MODES_V1

        for key in ("pipelined_speedup", "pipelined_1f1b_speedup"):
            speedups = doc.get(key)
            if isinstance(speedups, dict) and not speedups:
                self.error(f"measured run with empty '{key}' — the gate "
                           "section is missing its per-model numbers")

        watermark = doc.get("activation_watermark")
        if isinstance(watermark, dict):
            models = {k: v for k, v in watermark.items() if isinstance(v, dict)}
            if not models:
                self.error("measured run with no per-model "
                           "'activation_watermark' entries")
            for model, entry in models.items():
                where = f"activation_watermark.{model}"
                for field in WATERMARK_FIELDS:
                    self.require(entry, field, (int, float, bool), where)
                self.check_gates_true(entry, where)

        residency = doc.get("device_residency")
        if isinstance(residency, dict):
            models = {k: v for k, v in residency.items() if isinstance(v, dict)}
            if not models:
                self.error("measured run with no per-model "
                           "'device_residency' entries")
            for model, entry in models.items():
                where = f"device_residency.{model}"
                for mode in residency_modes:
                    transfers = self.require(entry, mode, dict, where)
                    if transfers is None:
                        continue
                    for field in transfer_fields:
                        self.require(transfers, field, (int, float),
                                     f"{where}.{mode}")
                    if (schema >= 3 and mode == "pipelined-1f1b-per-stage"
                            and transfers.get("link_staged", 0) != 0):
                        self.error(
                            f"{where}.{mode}.link_staged is "
                            f"{transfers.get('link_staged')!r} — a measured "
                            "per-stage run on this testbed must take the "
                            "direct link path (staged hops mean the fast "
                            "path silently degraded; see docs/BENCHMARKS.md "
                            "gate 5)")
                    if schema >= 4:
                        parts = [transfers.get(k) for k in
                                 ("link_overlapped", "link_blocking",
                                  "link_copies")]
                        if (all(isinstance(v, (int, float)) for v in parts)
                                and parts[0] + parts[1] != parts[2]):
                            self.error(
                                f"{where}.{mode}: link_overlapped "
                                f"({parts[0]}) + link_blocking ({parts[1]}) "
                                f"!= link_copies ({parts[2]}) — the overlap "
                                "split is a partition of all link copies")
                self.check_gates_true(entry, where)

        if schema >= 4:
            self.check_plane_mode_overlap(doc)
        if schema >= 5:
            self.check_optimizer_path(doc)
        if schema >= 6:
            self.check_transport(doc)

    def check_transport(self, doc: dict) -> None:
        """Schema-6 gate 9: wire transport billing + WAN shaping floors."""
        section = doc.get("transport")
        if not isinstance(section, dict):
            return
        models = {k: v for k, v in section.items() if isinstance(v, dict)}
        if not models:
            self.error("measured schema>=6 run with no per-model "
                       "'transport' entries")
        for model, entry in models.items():
            where = f"transport.{model}"
            inproc = self.require(entry, "in-process", dict, where)
            tcp = self.require(entry, "tcp-loopback", dict, where)
            self.require(entry, "gate_tcp_wire_billed", bool, where)
            for leg, transfers in (("in-process", inproc),
                                   ("tcp-loopback", tcp)):
                if not isinstance(transfers, dict):
                    continue
                for field in TRANSFER_FIELDS_V6:
                    self.require(transfers, field, (int, float),
                                 f"{where}.{leg}")
                parts = [transfers.get(k) for k in
                         ("link_overlapped", "link_blocking", "link_copies")]
                if (all(isinstance(v, (int, float)) for v in parts)
                        and parts[0] + parts[1] != parts[2]):
                    self.error(
                        f"{where}.{leg}: link_overlapped ({parts[0]}) + "
                        f"link_blocking ({parts[1]}) != link_copies "
                        f"({parts[2]}) — the overlap split must partition "
                        "all link copies on every transport")
            if isinstance(tcp, dict):
                wire = tcp.get("link_wire_bytes")
                payload = tcp.get("link_bytes")
                if isinstance(wire, (int, float)) and wire == 0:
                    self.error(
                        f"{where}.tcp-loopback.link_wire_bytes is 0 — a "
                        "measured tcp row that moved no frames means the "
                        "wire transport silently fell back to in-process "
                        "links (see docs/BENCHMARKS.md gate 9)")
                elif (isinstance(wire, (int, float))
                        and isinstance(payload, (int, float))
                        and not wire > payload):
                    self.error(
                        f"{where}.tcp-loopback: link_wire_bytes ({wire}) is "
                        f"not above link_bytes ({payload}) — CFW1 frames "
                        "carry a header on top of every payload")
                wns = tcp.get("link_wire_ns")
                if isinstance(wns, (int, float)) and wns == 0:
                    self.error(
                        f"{where}.tcp-loopback.link_wire_ns is 0 — frames "
                        "cannot cross a socket in zero time")
            if isinstance(inproc, dict):
                billed = [inproc.get(k) for k in
                          ("link_wire_bytes", "link_wire_ns")]
                if any(isinstance(v, (int, float)) and v != 0
                       for v in billed):
                    self.error(
                        f"{where}.in-process bills wire traffic "
                        f"(bytes {billed[0]!r}, ns {billed[1]!r}) — "
                        "in-process links never touch a socket")
            shaped = entry.get("shaped")
            if isinstance(shaped, dict):
                self.check_shaped(shaped, f"{where}.shaped")
            self.check_gates_true(entry, where)

    def check_shaped(self, shaped: dict, where: str) -> None:
        """Recompute each shaped link's floor from WAN_LATENCY_MS; the
        recorded ``floor_ns`` is informative, never trusted."""
        profile = self.require(shaped, "profile", str, where)
        scale = self.require(shaped, "scale", (int, float), where)
        links = self.require(shaped, "links", list, where)
        self.require(shaped, "gate_shaped_above_floor", bool, where)
        if profile is not None and profile != "gcp-5region":
            self.error(f"{where}: unknown WAN profile {profile!r}")
            return
        if not isinstance(links, list) or not isinstance(scale, (int, float)):
            return
        if not links:
            self.error(f"{where}: measured shaped section with no links — "
                       "the floor gate has no evidence")
        for i, link in enumerate(links):
            lw = f"{where}.links[{i}]"
            if not isinstance(link, dict):
                self.error(f"{lw} is not an object")
                continue
            src = self.require(link, "src_region", str, lw)
            dst = self.require(link, "dst_region", str, lw)
            mean = self.require(link, "mean_link_ns", (int, float), lw)
            self.require(link, "floor_ns", (int, float), lw)
            if src not in WAN_REGIONS or dst not in WAN_REGIONS:
                self.error(f"{lw}: unknown region pair {src!r} -> {dst!r}")
                continue
            if not isinstance(mean, (int, float)):
                continue
            floor_ns = (scale
                        * WAN_LATENCY_MS[WAN_REGIONS.index(src)]
                                        [WAN_REGIONS.index(dst)]
                        * 1e6)
            # +1 ns absorbs the bench's integer truncation of the delay.
            if mean + 1 < floor_ns:
                self.error(
                    f"{lw}: mean_link_ns ({mean}) sits below the netsim "
                    f"floor ({floor_ns:.0f} ns = scale x one-way "
                    f"{src} -> {dst} latency) — the shaper let a transfer "
                    "beat physics (see docs/BENCHMARKS.md gate 9)")
        self.check_gates_true(shaped, where)

    def check_optimizer_path(self, doc: dict) -> None:
        """Schema-5 gate 8: fused on-plane Adam vs the host optimizer."""
        section = self.require(doc, "optimizer_path", dict)
        if not isinstance(section, dict):
            return
        mb = section.get("microbatches")
        models = {k: v for k, v in section.items() if isinstance(v, dict)}
        if not models:
            self.error("measured schema>=5 run with no per-model "
                       "'optimizer_path' entries")
        for model, entry in models.items():
            where = f"optimizer_path.{model}"
            host = self.require(entry, "host", dict, where)
            device = self.require(entry, "device", dict, where)
            for field in OPTIMIZER_PATH_FIELDS_V5:
                self.require(entry, field, (int, float, bool), where)
            for leg, transfers in (("host", host), ("device", device)):
                if isinstance(transfers, dict):
                    for field in TRANSFER_FIELDS_V5:
                        self.require(transfers, field, (int, float),
                                     f"{where}.{leg}")
            if isinstance(device, dict):
                pulls = device.get("param_pulls")
                if isinstance(pulls, (int, float)) and pulls != 0:
                    self.error(
                        f"{where}.device.param_pulls is {pulls!r} — the "
                        "device optimizer never pulls parameters at steady "
                        "state; pulls belong to recovery/checkpoint "
                        "boundaries only (see docs/BENCHMARKS.md gate 8)")
                syncs = device.get("host_syncs")
                if (isinstance(mb, (int, float))
                        and isinstance(syncs, (int, float))
                        and syncs != mb * 4):
                    self.error(
                        f"{where}.device.host_syncs ({syncs}) != "
                        f"microbatches*4 ({mb * 4}) — the device path's "
                        "only remaining host traffic is the per-microbatch "
                        "loss + head gradient boundary (see "
                        "docs/BENCHMARKS.md gate 8)")
                if isinstance(host, dict):
                    hsyncs = host.get("host_syncs")
                    if (isinstance(syncs, (int, float))
                            and isinstance(hsyncs, (int, float))
                            and not syncs < hsyncs):
                        self.error(
                            f"{where}: device host_syncs ({syncs}) is not "
                            f"strictly below the host optimizer's "
                            f"({hsyncs}) — killing the m·L·P term is the "
                            "point of the device path (see "
                            "docs/BENCHMARKS.md gate 8)")
            self.check_gates_true(entry, where)

    def check_plane_mode_overlap(self, doc: dict) -> None:
        """Schema-4 gate 7: per-stage link wait, prefetch on vs off."""
        plane = self.require(doc, "plane_mode", dict)
        if not isinstance(plane, dict):
            return
        models = {k: v for k, v in plane.items() if isinstance(v, dict)}
        if not models:
            self.error("measured schema>=4 run with no per-model "
                       "'plane_mode' entries")
        for model, entry in models.items():
            where = f"plane_mode.{model}"
            on = self.require(entry, "link_wait_ns_overlap_on", list, where)
            off = self.require(entry, "link_wait_ns_overlap_off", list, where)
            self.require(entry, "gate_overlap_wait_below_off", bool, where)
            if isinstance(on, list) and isinstance(off, list):
                if len(on) != len(off):
                    self.error(f"{where}: overlap wait arrays differ in "
                               f"length ({len(on)} vs {len(off)}) — both are "
                               "indexed by stage")
                else:
                    for i, (a, b) in enumerate(zip(on, off)):
                        if not (isinstance(a, (int, float))
                                and isinstance(b, (int, float))):
                            self.error(f"{where}: overlap wait arrays must "
                                       f"be numeric (stage {i})")
                            continue
                        if a == 0 and b == 0:
                            continue  # stage moved no cross-plane bytes
                        if not (b > 0 and a < b):
                            self.error(
                                f"{where}: stage {i} link wait with overlap "
                                f"on ({a} ns) is not below overlap off "
                                f"({b} ns) — prefetch must take link time "
                                "off the consumer's critical path (see "
                                "docs/BENCHMARKS.md gate 7)")
            self.check_gates_true(entry, where)

    def check_recovery(self, doc: dict, status, schema) -> None:
        latencies = self.require(doc, "simulated_latencies", list)
        self.require(doc, "microbench", list)
        if schema >= 2:
            self.require(doc, "policy", dict)
        if status != "measured":
            return
        if not latencies:
            self.error("measured run with empty 'simulated_latencies'")
            return
        for i, entry in enumerate(latencies):
            where = f"simulated_latencies[{i}]"
            if not isinstance(entry, dict):
                self.error(f"{where} is not an object")
                continue
            for field in LATENCY_FIELDS:
                self.require(entry, field, (str, int, float), where)
        if schema >= 2:
            self.check_recovery_policy(doc)

    def check_recovery_policy(self, doc: dict) -> None:
        """Schema-2 policy gate: the burst_storm tape replay. Both gates
        are recomputed from the raw per-strategy runs — a bench that
        self-reports ``gate_*: true`` over losing numbers still fails."""
        policy = doc.get("policy")
        if not isinstance(policy, dict):
            return
        self.require(policy, "tape", str, "policy")
        runs = self.require(policy, "runs", list, "policy")
        self.require(policy, "adaptive_switch_iterations", list, "policy")
        self.require(policy, "tiercheck_restore_storage_bytes", (int, float),
                     "policy")
        if not isinstance(runs, list):
            return
        if not runs:
            self.error("measured schema>=2 recovery run with empty "
                       "'policy.runs' — the tape replay is the gate's "
                       "evidence")
            return
        walls: dict[str, float] = {}
        for i, run in enumerate(runs):
            where = f"policy.runs[{i}]"
            if not isinstance(run, dict):
                self.error(f"{where} is not an object")
                continue
            for field in POLICY_RUN_FIELDS:
                self.require(run, field, (str, int, float), where)
            name = run.get("strategy")
            wall = run.get("wall_clock_s")
            if isinstance(name, str) and isinstance(wall, (int, float)):
                walls[name] = wall
            if (run.get("strategy") == "tiercheck"
                    and isinstance(run.get("restore_storage_bytes"),
                                   (int, float))
                    and run["restore_storage_bytes"] != 0):
                self.error(
                    f"{where}: tiercheck restore moved "
                    f"{run['restore_storage_bytes']!r} storage bytes — the "
                    "in-memory neighbour tier must restore with zero "
                    "storage round-trip (see docs/BENCHMARKS.md)")
        if "adaptive" not in walls:
            self.error("policy.runs has no 'adaptive' entry — the policy "
                       "gate compares adaptive against every static "
                       "strategy")
        else:
            adaptive = walls["adaptive"]
            for name, wall in sorted(walls.items()):
                if name == "adaptive":
                    continue
                if not adaptive < wall:
                    self.error(
                        f"policy: adaptive wall_clock_s ({adaptive}) is not "
                        f"below {name}'s ({wall}) — live policy selection "
                        "must strictly beat every static strategy on the "
                        "committed tape (see docs/BENCHMARKS.md)")
        self.check_gates_true(policy, "policy")

    def check_coverage(self, doc: dict, status) -> None:
        scales = self.require(doc, "scales", list)
        strategies = self.require(doc, "strategies", list)
        processes = self.require(doc, "churn_processes", list)
        cells = self.require(doc, "cells", list)
        if status != "measured":
            return

        for key, values in (("scales", scales), ("strategies", strategies),
                            ("churn_processes", processes)):
            if isinstance(values, list) and not values:
                self.error(f"measured run with empty '{key}' — the matrix "
                           "has no extent along that axis")
        if not isinstance(cells, list):
            return
        if not cells:
            self.error("measured run with empty 'cells' — the coverage "
                       "matrix is the whole artifact")
            return

        if all(isinstance(v, list) for v in (scales, strategies, processes)):
            expected = len(scales) * len(strategies) * len(processes)
            if expected and len(cells) != expected:
                self.error(
                    f"cells has {len(cells)} entries but the declared axes "
                    f"span {len(scales)}x{len(strategies)}x{len(processes)} "
                    f"= {expected} — a measured matrix must be complete, "
                    "no silently dropped cells")

        if isinstance(scales, list):
            numeric = [s for s in scales if isinstance(s, (int, float))]
            top = max(numeric) if numeric else 0
            if top < COVERAGE_MIN_TOP_SCALE:
                self.error(
                    f"largest scale ({top}) is below the "
                    f"{COVERAGE_MIN_TOP_SCALE}-stage coverage floor — the "
                    "thousand-stage scale-out is the point of this artifact "
                    "(see docs/BENCHMARKS.md)")

        for i, cell in enumerate(cells):
            where = f"cells[{i}]"
            if not isinstance(cell, dict):
                self.error(f"{where} is not an object")
                continue
            for field in COVERAGE_CELL_FIELDS:
                self.require(cell, field, (str, int, float, bool), where)
            sampled = cell.get("sampled_iterations")
            iters = cell.get("iterations")
            if (isinstance(sampled, (int, float)) and isinstance(iters, (int, float))
                    and sampled > iters):
                self.error(f"{where}: sampled_iterations ({sampled}) exceeds "
                           f"iterations ({iters}) — the event-driven walk "
                           "cannot consult the injector more often than once "
                           "per iteration")
            rec = cell.get("recoveries")
            fails = cell.get("failures")
            if (isinstance(rec, (int, float)) and isinstance(fails, (int, float))
                    and rec > fails):
                self.error(f"{where}: recoveries ({rec}) exceeds failures "
                           f"({fails}) — every recovery is triggered by a "
                           "failed iteration")

        gates = self.require(doc, "gates", dict)
        if isinstance(gates, dict):
            self.check_gates_true(gates, "gates")


def selftest() -> int:
    """Run the checker against the committed fixtures: the good one must
    pass clean, the bad-wait one must be rejected *for the overlap gate*
    (not for some incidental structural reason). This is the cargo-less
    CI proof that gate 7 actually has teeth."""
    fixtures = Path(__file__).resolve().parent / "fixtures"
    ok = True

    good = Checker(fixtures / "bench_schema4_good.json")
    good.check()
    if good.errors:
        ok = False
        print("selftest FAIL: good fixture rejected:", file=sys.stderr)
        for err in good.errors:
            print(f"  {err}", file=sys.stderr)

    bad = Checker(fixtures / "bench_schema4_bad_wait.json")
    bad.check()
    if not any("is not below overlap off" in err for err in bad.errors):
        ok = False
        print("selftest FAIL: bad-wait fixture was not rejected for the "
              "overlap wait gate; errors were:", file=sys.stderr)
        for err in bad.errors or ["<none>"]:
            print(f"  {err}", file=sys.stderr)

    good5 = Checker(fixtures / "bench_schema5_good.json")
    good5.check()
    if good5.errors:
        ok = False
        print("selftest FAIL: good schema-5 fixture rejected:", file=sys.stderr)
        for err in good5.errors:
            print(f"  {err}", file=sys.stderr)

    bad5 = Checker(fixtures / "bench_schema5_bad_pulls.json")
    bad5.check()
    if not any("never pulls parameters at steady state" in err
               for err in bad5.errors):
        ok = False
        print("selftest FAIL: bad-pulls fixture was not rejected for the "
              "steady-state param-pull gate; errors were:", file=sys.stderr)
        for err in bad5.errors or ["<none>"]:
            print(f"  {err}", file=sys.stderr)

    good6 = Checker(fixtures / "bench_schema6_good.json")
    good6.check()
    if good6.errors:
        ok = False
        print("selftest FAIL: good schema-6 fixture rejected:", file=sys.stderr)
        for err in good6.errors:
            print(f"  {err}", file=sys.stderr)

    bad6 = Checker(fixtures / "bench_schema6_bad_wire.json")
    bad6.check()
    if not any("silently fell back" in err for err in bad6.errors):
        ok = False
        print("selftest FAIL: bad-wire fixture was not rejected for the "
              "zero-wire-bytes gate; errors were:", file=sys.stderr)
        for err in bad6.errors or ["<none>"]:
            print(f"  {err}", file=sys.stderr)

    bad6f = Checker(fixtures / "bench_schema6_bad_floor.json")
    bad6f.check()
    if not any("below the netsim floor" in err for err in bad6f.errors):
        ok = False
        print("selftest FAIL: bad-floor fixture was not rejected for the "
              "shaped floor gate (the checker must recompute floors, not "
              "trust floor_ns); errors were:", file=sys.stderr)
        for err in bad6f.errors or ["<none>"]:
            print(f"  {err}", file=sys.stderr)

    rec_good = Checker(fixtures / "recovery_schema2_good.json")
    rec_good.check()
    if rec_good.errors:
        ok = False
        print("selftest FAIL: good recovery fixture rejected:",
              file=sys.stderr)
        for err in rec_good.errors:
            print(f"  {err}", file=sys.stderr)

    rec_bad = Checker(fixtures / "recovery_schema2_bad_policy.json")
    rec_bad.check()
    if not any("is not below" in err for err in rec_bad.errors):
        ok = False
        print("selftest FAIL: bad-policy recovery fixture was not rejected "
              "for the adaptive-beats-static gate; errors were:",
              file=sys.stderr)
        for err in rec_bad.errors or ["<none>"]:
            print(f"  {err}", file=sys.stderr)
    if not any("zero storage round-trip" in err for err in rec_bad.errors):
        ok = False
        print("selftest FAIL: bad-policy recovery fixture was not rejected "
              "for the tiercheck zero-storage gate; errors were:",
              file=sys.stderr)
        for err in rec_bad.errors or ["<none>"]:
            print(f"  {err}", file=sys.stderr)

    cov_good = Checker(fixtures / "coverage_schema1_good.json")
    cov_good.check()
    if cov_good.errors:
        ok = False
        print("selftest FAIL: good coverage fixture rejected:",
              file=sys.stderr)
        for err in cov_good.errors:
            print(f"  {err}", file=sys.stderr)

    cov_bad = Checker(fixtures / "coverage_schema1_bad_scale.json")
    cov_bad.check()
    if not any("coverage floor" in err for err in cov_bad.errors):
        ok = False
        print("selftest FAIL: bad-scale coverage fixture was not rejected "
              "for the thousand-stage floor; errors were:", file=sys.stderr)
        for err in cov_bad.errors or ["<none>"]:
            print(f"  {err}", file=sys.stderr)

    print("selftest ok" if ok else "selftest FAILED",
          file=sys.stdout if ok else sys.stderr)
    return 0 if ok else 1


def main(argv: list[str]) -> int:
    if argv == ["--selftest"]:
        return selftest()
    repo_root = Path(__file__).resolve().parent.parent
    paths = [Path(p) for p in argv] or sorted(repo_root.glob("BENCH_*.json"))
    if not paths:
        print("check_bench_json: no BENCH_*.json files found", file=sys.stderr)
        return 1
    failures = 0
    for path in paths:
        checker = Checker(path)
        checker.check()
        if checker.errors:
            failures += 1
            for err in checker.errors:
                print(f"FAIL {err}", file=sys.stderr)
        else:
            print(f"ok   {path}")
    if failures:
        print(f"check_bench_json: {failures}/{len(paths)} file(s) invalid",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
